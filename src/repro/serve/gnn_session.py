"""Compiled graph sessions: the (graph, model) serving artifact.

A ``GraphStore`` registers graphs (host-side ``GraphData``) and models
(family + full-precision params) and compiles a ``CompiledGraphSession`` per
(graph, model) pair:

  * FRDC-encoded adjacencies of every kind the family's packed forward needs
    (GCN: normalized + 0/1; SAGE: mean-normalized; SAINT: 0/1 sum);
  * bit-packed quantized weights (``quantize_gcn`` / ``quantize_sage`` /
    ``quantize_saint``);
  * a tuner-selected variant plan (reusing :mod:`repro.core.tuner` over the
    legal :mod:`repro.core.abstraction` pairings), timed on the actual graph;
  * full-graph BN calibration: the per-site (mu, sd) batch-norm statistics —
    the ONLY cross-node statistic in any bitgnn forward — are frozen from one
    full-graph pass, so a k-hop subgraph forward reproduces the full-graph
    computation for the seed nodes exactly (fp-reassociation noise only);
  * a cached full-graph logits fast path, invalidated on feature update.

Artifacts are serialized through the existing async checkpointer
(:mod:`repro.checkpoint.checkpointer`): array state in ``step_0/shard_0.npz``
plus a ``plan.json`` sidecar holding the plan, static FRDC dims and a feature
fingerprint; a store restart with an unchanged graph/model restores instead
of re-tuning.

Subgraph forwards are served through HIGH-WATER SHAPE BUCKETS: node and FRDC
group counts are padded up to pow2 marks that only ever grow (capped at the
full graph), so the per-session jitted forward converges to one steady
padded shape after a short warmup and never recompiles in steady state
(``compile_count`` counts jit traces and is the verification counter).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import frdc, tuner
from repro.core.bspmm import TRINARY_DEFAULT
from repro.graphs import sampling
from repro.graphs.datasets import GraphData
from repro.models import gnn

FAMILIES = ("gcn", "sage", "saint")

# layer_variants of the two legal GCN end-to-end schemes (paper Table 3);
# SAGE/SAINT run the fixed Fig. 2 pipeline (BMM.BBF branches + BSpMM.FBF).
_GCN_SCHEME_VARIANTS = {
    "full": (("BMM.BBF", "BSpMM.FBF"), ("BMM.BBF", "BSpMM.FBF")),
    "bin": (("BMM.FBB", "BSpMM.BBB"), ("BMM.BBF", "BSpMM.FBF")),
}
_FIXED_VARIANTS = (("BMM.BBF", "BSpMM.FBF"), ("BMM.BBF", "BSpMM.FBF"))


def bucket_pow2(n: int, floor: int, cap: Optional[int] = None) -> int:
    """Round up to the power-of-two bucket grid (>= floor, <= cap)."""
    b = floor
    while b < n:
        b *= 2
    return b if cap is None else min(b, cap)


@dataclasses.dataclass
class SessionPlan:
    """Tuner-selected execution plan of one compiled session."""
    family: str
    scheme: str                       # gcn: "full" | "bin"; else "fixed"
    trinary_mode: str = TRINARY_DEFAULT
    layer_variants: tuple = _FIXED_VARIANTS
    tuned_latency_s: float = float("nan")
    output_delta: float = float("nan")

    def name(self) -> str:
        layers = ";".join(f"{m}+{s}" for m, s in self.layer_variants)
        return f"{self.family}/{self.scheme}[{layers}|{self.trinary_mode}]"

    def to_json(self) -> dict:
        return dict(family=self.family, scheme=self.scheme,
                    trinary_mode=self.trinary_mode,
                    layer_variants=[list(v) for v in self.layer_variants],
                    tuned_latency_s=self.tuned_latency_s,
                    output_delta=self.output_delta)

    @classmethod
    def from_json(cls, d: dict) -> "SessionPlan":
        return cls(family=d["family"], scheme=d["scheme"],
                   trinary_mode=d["trinary_mode"],
                   layer_variants=tuple(tuple(v) for v in d["layer_variants"]),
                   tuned_latency_s=d.get("tuned_latency_s", float("nan")),
                   output_delta=d.get("output_delta", float("nan")))


@dataclasses.dataclass
class GraphEntry:
    name: str
    data: GraphData
    version: int = 0
    _csr: Optional[sampling.CSRGraph] = None
    _dinv_gcn: Optional[np.ndarray] = None
    _dinv_mean: Optional[np.ndarray] = None

    @property
    def csr(self) -> sampling.CSRGraph:
        if self._csr is None:
            self._csr = sampling.to_csr(self.data.edges, self.data.n_nodes)
        return self._csr

    @property
    def dinv_gcn(self) -> np.ndarray:
        """Full-graph D^-1/2 (self-loops included) — GCN factorization vector.
        Subgraph adjacencies index into THIS so seed rows aggregate with the
        exact full-graph normalization."""
        if self._dinv_gcn is None:
            n = self.data.n_nodes
            deg = np.bincount(self.data.edges[0], minlength=n) + 1.0
            self._dinv_gcn = 1.0 / np.sqrt(deg)
        return self._dinv_gcn

    @property
    def dinv_mean(self) -> np.ndarray:
        if self._dinv_mean is None:
            n = self.data.n_nodes
            deg = np.bincount(self.data.edges[0], minlength=n).astype(
                np.float64)
            self._dinv_mean = 1.0 / np.maximum(deg, 1.0)
        return self._dinv_mean


@dataclasses.dataclass
class ModelEntry:
    name: str
    family: str
    params: object


def _quantize(family: str, params):
    return {"gcn": gnn.quantize_gcn, "sage": gnn.quantize_sage,
            "saint": gnn.quantize_saint}[family](params)


def _frdc_arrays(m: frdc.FRDCMatrix) -> dict:
    out = dict(tiles=m.tiles, col_idx=m.col_idx, group_row=m.group_row,
               group_first=m.group_first, grp_ptr=m.grp_ptr)
    if m.row_scale is not None:
        out["row_scale"] = m.row_scale
    if m.col_scale is not None:
        out["col_scale"] = m.col_scale
    return out


def _frdc_rebuild(arrs: dict, n_rows: int, n_cols: int,
                  nnz: int = 0) -> frdc.FRDCMatrix:
    return frdc.FRDCMatrix(
        tiles=arrs["tiles"], col_idx=arrs["col_idx"],
        group_row=arrs["group_row"], group_first=arrs["group_first"],
        grp_ptr=arrs["grp_ptr"], n_rows=int(n_rows), n_cols=int(n_cols),
        nnz=int(nnz), row_scale=arrs.get("row_scale"),
        col_scale=arrs.get("col_scale"))


def _feature_fingerprint(x: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(x).tobytes()).hexdigest()[:16]


def _session_fingerprint(graph: "GraphEntry", model: "ModelEntry") -> dict:
    d = graph.data
    return dict(graph=graph.name, model=model.name, family=model.family,
                n_nodes=int(d.n_nodes), n_edges=int(d.n_edges),
                features=_feature_fingerprint(d.x))


# FRDC array fields per adjacency kind of each family — the (deterministic)
# pytree structure of a saved artifact, so load() can build the restore
# template without encoding any adjacency.
_FRDC_BASE_FIELDS = ("tiles", "col_idx", "group_row", "group_first",
                     "grp_ptr")
_ADJ_SCALE_FIELDS = {
    "gcn": {"adj": ("row_scale", "col_scale"), "bin": ()},
    "sage": {"mean": ("row_scale",)},
    "saint": {"sum": ()},
}


def _adj_like(family: str) -> dict:
    return {kind: {f: np.zeros(0) for f in _FRDC_BASE_FIELDS + extra}
            for kind, extra in _ADJ_SCALE_FIELDS[family].items()}


def _coerce_quant(q):
    """Re-type a checkpoint-restored quantized param tree: the static ``n``
    field of each BinTensor round-trips through npz as a 0-d array and must
    come back as a python int (it participates in jit-static shape logic)."""
    from repro.core.binarize import BinTensor
    return type(q)(*(BinTensor(packed=jnp.asarray(t.packed),
                               scale=jnp.asarray(t.scale), n=int(t.n))
                     for t in q))


class CompiledGraphSession:
    """Per-(graph, model) compiled serving artifact. See module docstring."""

    NODE_BUCKET_FLOOR = 64
    GROUP_BUCKET_FLOOR = 16

    def __init__(self, graph: GraphEntry, model: ModelEntry,
                 plan: SessionPlan, qparams, khop: int = 2,
                 max_batch: int = 32,
                 adj_full: Optional[Dict[str, frdc.FRDCMatrix]] = None):
        self.graph = graph
        self.model = model
        self.plan = plan
        self.qparams = qparams
        self.khop = khop
        self.max_batch = max_batch
        self.key = f"{graph.name}__{model.name}"
        self.feature_version = -1          # forces first sync to calibrate
        self.bn: Optional[tuple] = None
        self._x_dev: Optional[jax.Array] = None
        self._full_cache: Optional[np.ndarray] = None
        self._n_traces = 0                 # jit cache-miss counter
        self._invalidations = 0
        # high-water shape buckets: node and group pads only ever GROW (in
        # pow2 steps, capped at the full graph), so a session converges to
        # one steady padded shape and serving stops recompiling — warmup is
        # a handful of max-width batches, not a probabilistic shape sweep.
        self._n_water = 0
        self._g_water: Dict[Tuple[int, str], int] = {}
        # adj_full injected on artifact restore (skips re-encoding the graph)
        self._adj_full = (adj_full if adj_full is not None
                          else self._build_full_adjacencies())
        self._jit_full = self._make_full_fn()
        self._jit_serve = self._make_serve_fn()

    # ------------------------------------------------------------ build ----
    def _build_full_adjacencies(self) -> Dict[str, frdc.FRDCMatrix]:
        d = self.graph.data
        fam = self.plan.family
        if fam == "gcn":
            return {"adj": d.adjacency("gcn"), "bin": d.adjacency("binary")}
        if fam == "sage":
            return {"mean": d.adjacency("mean")}
        return {"sum": d.adjacency("binary")}

    def _forward(self, qparams, x, adjs: Dict[str, frdc.FRDCMatrix], **kw):
        fam = self.plan.family
        if fam == "gcn":
            return gnn.gcn_forward_bitgnn(
                qparams, x, adjs["adj"], adjs["bin"], scheme=self.plan.scheme,
                trinary_mode=self.plan.trinary_mode, **kw)
        if fam == "sage":
            return gnn.sage_forward_bitgnn(qparams, x, adjs["mean"], **kw)
        return gnn.saint_forward_bitgnn(qparams, x, adjs["sum"], **kw)

    def _make_full_fn(self):
        # qparams/adjacencies are closed over (jit constants): BinTensor's
        # static ``n`` and FRDCMatrix's static dims must not be traced. The
        # jitted fns are recreated whenever qparams are swapped (load()).
        adjs, qparams = self._adj_full, self.qparams

        def full(x):
            return self._forward(qparams, x, adjs, return_bn_stats=True)

        return jax.jit(full)

    def _make_serve_fn(self):
        """The bucket-shaped subgraph forward. One ``jax.jit`` per session;
        jit's shape-keyed cache gives one compile per (node bucket, group
        buckets) combination. ``self._n_traces`` increments on trace only
        (python side effect), i.e. it IS the jit cache-miss counter."""
        qparams = self.qparams

        def serve(x, bn, adjs, seeds):
            self._n_traces += 1
            n_pad = x.shape[0]
            mats = {k: _frdc_rebuild(v, n_pad, n_pad)
                    for k, v in adjs.items()}
            out = self._forward(qparams, x, mats, bn_stats=bn)
            return out[seeds]

        return jax.jit(serve)

    # ------------------------------------------------------------- sync ----
    def sync(self) -> None:
        """Adopt the store's current features: re-upload, recalibrate BN and
        refresh the full-graph logits cache. No-op when already current."""
        if self.feature_version == self.graph.version:
            return
        invalidated = self.feature_version >= 0
        self._x_dev = jnp.asarray(self.graph.data.x)
        out, bn = self._jit_full(self._x_dev)
        self.bn = bn
        self._full_cache = np.asarray(out)
        self.feature_version = self.graph.version
        if invalidated:
            self._invalidations += 1

    @property
    def invalidations(self) -> int:
        return self._invalidations

    @property
    def compile_count(self) -> int:
        """Number of jit traces of the bucketed subgraph forward."""
        return self._n_traces

    # ------------------------------------------------------ full path ------
    def full_logits(self) -> np.ndarray:
        """Cached full-graph inference (the fast path for small/warm graphs)."""
        self.sync()
        return self._full_cache

    # -------------------------------------------------- subgraph path ------
    def _sub_adjacency(self, sub_nodes: np.ndarray,
                       sub_edges: np.ndarray) -> Dict[str, frdc.FRDCMatrix]:
        """Per-family subgraph FRDC matrices carrying FULL-graph factorization
        vectors, so seed-row aggregation is identical to the full graph."""
        fam = self.plan.family
        ns = sub_nodes.size
        if fam == "gcn":
            loops = np.arange(ns, dtype=np.int64)
            r = np.concatenate([sub_edges[0], loops])
            c = np.concatenate([sub_edges[1], loops])
            dinv = self.graph.dinv_gcn[sub_nodes]
            return {
                "adj": frdc.from_coo(r, c, ns, ns, row_scale=dinv,
                                     col_scale=dinv),
                "bin": frdc.from_coo(sub_edges[0], sub_edges[1], ns, ns),
            }
        if fam == "sage":
            return {"mean": frdc.from_coo(
                sub_edges[0], sub_edges[1], ns, ns,
                row_scale=self.graph.dinv_mean[sub_nodes])}
        return {"sum": frdc.from_coo(sub_edges[0], sub_edges[1], ns, ns)}

    @property
    def _node_cap(self) -> int:
        return self._adj_full[next(iter(self._adj_full))].n_tile_rows \
            * frdc.TILE

    def _extract(self, uniq_seeds: np.ndarray):
        """Host-side k-hop extraction + subgraph FRDC build (no device work
        — also used by warmup to probe steady-state shapes cheaply)."""
        sub_nodes, sub_edges, seed_pos = sampling.khop_subgraph(
            self.graph.csr, uniq_seeds, self.khop)
        return sub_nodes, self._sub_adjacency(sub_nodes, sub_edges), seed_pos

    def serve_subgraph(self, seeds: np.ndarray) -> np.ndarray:
        """Micro-batched node-level inference: k-hop extraction -> bucket
        padding -> jitted forward -> (len(seeds), n_out) logits."""
        self.sync()
        seeds = np.asarray(seeds, np.int64)
        uniq, inverse = np.unique(seeds, return_inverse=True)
        sub_nodes, mats, seed_pos = self._extract(uniq)

        n_pad = bucket_pow2(max(sub_nodes.size, self._n_water),
                            self.NODE_BUCKET_FLOOR, self._node_cap)
        self._n_water = n_pad
        adjs = {}
        for k, m in mats.items():
            wkey = (n_pad, k)
            g_pad = max(self._g_water.get(wkey, 0),
                        bucket_pow2(m.n_groups, self.GROUP_BUCKET_FLOOR))
            self._g_water[wkey] = g_pad
            adjs[k] = _frdc_arrays(frdc.pad_frdc(m, n_pad, n_groups=g_pad))

        x_pad = np.zeros((n_pad, self.graph.data.x.shape[1]), np.float32)
        x_pad[:sub_nodes.size] = self.graph.data.x[sub_nodes]
        pos_pad = np.zeros((self.max_batch,), np.int32)
        pos_pad[:seed_pos.size] = seed_pos

        out = self._jit_serve(jnp.asarray(x_pad), self.bn, adjs,
                              jnp.asarray(pos_pad))
        return np.asarray(out)[:uniq.size][inverse]

    def warmup(self, rng: Optional[np.random.Generator] = None,
               probes: int = 16, margin: float = 1.125) -> int:
        """Drive the high-water shape bucket to its steady value and compile
        it. Probes ``probes`` max-width batches HOST-SIDE ONLY (k-hop +
        subgraph FRDC build, no device work, milliseconds each) to find the
        largest node/group counts the workload produces, sets the water
        marks to ``margin`` above that (then pow2-rounded), and runs one
        real forward to compile the steady shape. A workload batch can only
        recompile by exceeding the margined pow2 bucket — and the monotone
        water then absorbs it after one compile. Returns compiles triggered."""
        rng = rng or np.random.default_rng(0)
        before = self._n_traces
        self.sync()
        n = self.graph.data.n_nodes
        n_max, g_max = 0, {}
        for _ in range(probes):
            seeds = np.unique(rng.integers(0, n, size=self.max_batch))
            sub_nodes, mats, _ = self._extract(seeds)
            n_max = max(n_max, sub_nodes.size)
            for k, m in mats.items():
                g_max[k] = max(g_max.get(k, 0), m.n_groups)
        n_pad = bucket_pow2(min(int(n_max * margin), self._node_cap),
                            self.NODE_BUCKET_FLOOR, self._node_cap)
        self._n_water = max(self._n_water, n_pad)
        for k, g in g_max.items():
            wkey = (self._n_water, k)
            g_pad = bucket_pow2(int(g * margin), self.GROUP_BUCKET_FLOOR)
            self._g_water[wkey] = max(self._g_water.get(wkey, 0), g_pad)
        self.serve_subgraph(rng.integers(0, n, size=self.max_batch))
        return self._n_traces - before

    # ------------------------------------------------------- artifact ------
    def _state(self) -> dict:
        # bn stats are NOT serialized: they are a pure function of
        # (qparams, features) and the first sync() after load recomputes
        # them in the same full-graph pass that fills the logits cache.
        return {"qparams": self.qparams,
                "adj": {k: _frdc_arrays(m)
                        for k, m in self._adj_full.items()}}

    def fingerprint(self) -> dict:
        return _session_fingerprint(self.graph, self.model)

    def save(self, directory: Path) -> None:
        """Serialize the compiled artifact via the existing checkpointer:
        arrays in step_0, plan + static dims + fingerprint in plan.json."""
        self.sync()
        ckpt = Checkpointer(directory, keep=1)
        ckpt.save(0, self._state(), blocking=True)
        sidecar = dict(
            plan=self.plan.to_json(), fingerprint=self.fingerprint(),
            khop=self.khop, max_batch=self.max_batch,
            adj_dims={k: [m.n_rows, m.n_cols, m.nnz]
                      for k, m in self._adj_full.items()})
        (Path(directory) / "plan.json").write_text(json.dumps(sidecar))

    @classmethod
    def load(cls, directory: Path, graph: GraphEntry, model: ModelEntry,
             khop: Optional[int] = None, max_batch: Optional[int] = None
             ) -> Optional["CompiledGraphSession"]:
        """Restore a session artifact; returns None on any mismatch (missing
        files, different graph/model/features, or a khop/max_batch that
        differs from what the caller wants — a narrower restored seed-slot
        buffer would overflow under a wider engine) so the caller recompiles.

        All mismatch checks run BEFORE anything is built; the adjacency
        encode (the expensive part of a cold session build on large graphs)
        is skipped entirely — the FRDC arrays come from the checkpoint."""
        directory = Path(directory)
        sidecar_path = directory / "plan.json"
        if not sidecar_path.exists():
            return None
        sidecar = json.loads(sidecar_path.read_text())
        if khop is not None and sidecar["khop"] != khop:
            return None
        if max_batch is not None and sidecar["max_batch"] != max_batch:
            return None
        if _session_fingerprint(graph, model) != sidecar["fingerprint"]:
            return None
        plan = SessionPlan.from_json(sidecar["plan"])
        like = {"qparams": _quantize(model.family, model.params),
                "adj": _adj_like(model.family)}
        try:
            state = Checkpointer(directory, keep=1).restore(None, like)
        except (FileNotFoundError, AssertionError):
            return None
        dims = sidecar["adj_dims"]
        adj_full = {k: _frdc_rebuild(v, *dims[k])
                    for k, v in state["adj"].items()}
        return cls(graph, model, plan, _coerce_quant(state["qparams"]),
                   khop=sidecar["khop"], max_batch=sidecar["max_batch"],
                   adj_full=adj_full)


# ---------------------------------------------------------------------------
# Store
# ---------------------------------------------------------------------------

class GraphStore:
    """Registry of graphs + models producing cached compiled sessions."""

    def __init__(self, cache_dir: Optional[str] = None, khop: int = 2,
                 max_batch: int = 32):
        self.cache_dir = Path(cache_dir) if cache_dir else None
        self.khop = khop
        self.max_batch = max_batch
        self.graphs: Dict[str, GraphEntry] = {}
        self.models: Dict[str, ModelEntry] = {}
        self._sessions: Dict[Tuple[str, str], CompiledGraphSession] = {}

    # -------------------------------------------------------- registry ----
    def register_graph(self, name: str, data: GraphData) -> GraphEntry:
        entry = GraphEntry(name=name, data=data)
        self.graphs[name] = entry
        return entry

    def register_model(self, name: str, family: str, params) -> ModelEntry:
        if family not in FAMILIES:
            raise ValueError(f"unknown family {family!r}; have {FAMILIES}")
        entry = ModelEntry(name=name, family=family, params=params)
        self.models[name] = entry
        return entry

    def update_features(self, name: str, x: np.ndarray) -> None:
        """Swap node features in place; sessions recalibrate + drop their
        full-graph caches on next use (version-based invalidation)."""
        entry = self.graphs[name]
        x = np.asarray(x, np.float32)
        if x.shape != entry.data.x.shape:
            raise ValueError(f"feature shape {x.shape} != "
                             f"{entry.data.x.shape} (graph structure and "
                             f"feature width are fixed per registration)")
        entry.data.x = x
        entry.version += 1

    # --------------------------------------------------------- compile ----
    def session(self, graph: str, model: str, tune: bool = False,
                tune_repeats: int = 2) -> CompiledGraphSession:
        key = (graph, model)
        if key in self._sessions:
            return self._sessions[key]
        g, m = self.graphs[graph], self.models[model]

        sess = None
        sess_dir = (self.cache_dir / f"{graph}__{model}"
                    if self.cache_dir else None)
        if sess_dir is not None:
            sess = CompiledGraphSession.load(sess_dir, g, m, khop=self.khop,
                                             max_batch=self.max_batch)
        if sess is None:
            qparams = _quantize(m.family, m.params)
            plan = (self._tune_plan(g, m, qparams, repeats=tune_repeats)
                    if tune else self._default_plan(m.family))
            sess = CompiledGraphSession(g, m, plan, qparams, khop=self.khop,
                                        max_batch=self.max_batch)
            sess.sync()
            if sess_dir is not None:
                sess.save(sess_dir)
        self._sessions[key] = sess
        return sess

    @staticmethod
    def _default_plan(family: str) -> SessionPlan:
        if family == "gcn":
            return SessionPlan(family, "bin",
                               layer_variants=_GCN_SCHEME_VARIANTS["bin"])
        return SessionPlan(family, "fixed")

    def _tune_plan(self, g: GraphEntry, m: ModelEntry, qparams,
                   repeats: int = 2) -> SessionPlan:
        """Time the legal end-to-end variant assignments on the actual graph
        (paper §3.4) and pick the fastest."""
        x = jnp.asarray(g.data.x)
        if m.family == "gcn":
            adj, adj_bin = g.data.adjacency("gcn"), g.data.adjacency("binary")
            cands = [
                tuner.Candidate(_GCN_SCHEME_VARIANTS["full"], "s3_two_popc"),
                tuner.Candidate(_GCN_SCHEME_VARIANTS["bin"], "s3_two_popc"),
                tuner.Candidate(_GCN_SCHEME_VARIANTS["bin"], "s2_and_andnot"),
            ]

            def build(cand):
                scheme = ("bin" if cand.layer_variants[0][0] == "BMM.FBB"
                          else "full")
                def fwd(xx):
                    return gnn.gcn_forward_bitgnn(
                        qparams, xx, adj, adj_bin, scheme=scheme,
                        trinary_mode=cand.trinary_mode)
                return fwd
        else:
            adj = g.data.adjacency(
                "mean" if m.family == "sage" else "binary")
            fwd_fn = (gnn.sage_forward_bitgnn if m.family == "sage"
                      else gnn.saint_forward_bitgnn)
            cands = [tuner.Candidate(_FIXED_VARIANTS, TRINARY_DEFAULT)]

            def build(cand):
                def fwd(xx):
                    return fwd_fn(qparams, xx, adj)
                return fwd

        results = tuner.tune(build, (x,), cands, repeats=repeats)
        best = results[0]
        scheme = "fixed"
        if m.family == "gcn":
            scheme = ("bin" if best.candidate.layer_variants[0][0] ==
                      "BMM.FBB" else "full")
        return SessionPlan(
            family=m.family, scheme=scheme,
            trinary_mode=best.candidate.trinary_mode,
            layer_variants=best.candidate.layer_variants,
            tuned_latency_s=best.latency_s,
            output_delta=best.output_delta)
