"""Multi-tenant admission control + weighted fair scheduling for the GNN
serving engines.

Two concerns live here, both previously inlined (or absent) in
:class:`~repro.serve.gnn_engine.GNNServeEngine`:

  * **Admission** — every ``submit()`` is checked against the submitting
    tenant's :class:`TenantPolicy` BEFORE it touches a queue: a token bucket
    enforces the tenant's sustained rate (``rate_qps``, burst capacity
    ``burst``), and ``max_queue_depth`` bounds the tenant's queued backlog.
    The outcome is a typed :class:`AdmissionDecision` — ``accept`` /
    ``throttle`` (rate limit, with a ``retry_after_s`` hint) / ``shed``
    (overload) — attached to the returned query, NEVER an exception: one
    tenant blowing its quota must bounce back to that tenant's caller, not
    crash a tick that is also carrying other tenants' queries.

  * **Scheduling** — the engine's queue pick generalizes the lazy
    oldest-head heap to **weighted start-time fair queueing across
    tenants**: each tenant carries a virtual time that advances by
    ``batch_size / weight`` whenever one of its queues is served, and the
    pick goes to the backlogged tenant with the smallest virtual start tag
    (FIFO oldest-head WITHIN a tenant — with a single tenant this is
    exactly the pre-tenancy scheduler). Higher-weight tenants therefore
    drain proportionally faster under contention, while the **staleness
    bound** keeps the scheduler starvation-free: any queue head that has
    waited longer than ``staleness_bound_s`` preempts the virtual-time
    order and is served globally FIFO among the overdue — a weight-1 tenant
    behind a weight-100 firehose still sees every request picked within
    (roughly) the bound plus one batch service time.

The controller is NOT internally locked: the engine already serializes
queue surgery under its ``_qlock`` and calls every mutating method while
holding it.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from typing import Deque, Dict, List, Optional, Tuple

DEFAULT_TENANT = "default"

ACCEPT = "accept"
THROTTLE = "throttle"
SHED = "shed"


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Per-tenant serving contract.

    ``rate_qps``         sustained admission rate (token-bucket refill);
                         ``inf`` disables rate limiting.
    ``burst``            bucket capacity — how far above the sustained rate
                         a short spike may go; defaults to
                         ``max(1, rate_qps)`` (one second of traffic).
    ``weight``           scheduler share: under contention a tenant drains
                         proportionally to its weight (integer >= 1).
    ``max_queue_depth``  queued-backlog bound; submissions beyond it are
                         shed (``None`` = unbounded).
    """
    rate_qps: float = math.inf
    burst: Optional[float] = None
    weight: int = 1
    max_queue_depth: Optional[int] = None

    def __post_init__(self):
        if not self.rate_qps > 0:
            raise ValueError(f"rate_qps must be > 0, got {self.rate_qps}")
        if self.burst is not None and not self.burst >= 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        if int(self.weight) != self.weight or self.weight < 1:
            raise ValueError(f"weight must be an integer >= 1, "
                             f"got {self.weight}")
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError(f"max_queue_depth must be >= 1, "
                             f"got {self.max_queue_depth}")

    @property
    def bucket_capacity(self) -> float:
        if self.burst is not None:
            return float(self.burst)
        return math.inf if math.isinf(self.rate_qps) \
            else max(1.0, self.rate_qps)


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """Typed outcome of one ``submit()`` admission check."""
    action: str                      # ACCEPT | THROTTLE | SHED
    tenant: str
    reason: str = ""
    retry_after_s: float = 0.0

    @property
    def accepted(self) -> bool:
        return self.action == ACCEPT


class _TokenBucket:
    """Continuous-refill token bucket (one token per admitted query)."""

    __slots__ = ("rate", "capacity", "tokens", "t_last")

    def __init__(self, rate: float, capacity: float, now: float):
        self.rate = rate
        self.capacity = capacity
        self.tokens = capacity
        self.t_last = now

    def try_take(self, now: float) -> Tuple[bool, float]:
        """Take one token; returns (ok, retry_after_s)."""
        if math.isinf(self.rate):
            return True, 0.0
        self.tokens = min(self.capacity,
                          self.tokens + (now - self.t_last) * self.rate)
        self.t_last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True, 0.0
        return False, (1.0 - self.tokens) / self.rate


class AdmissionController:
    """Tenant policies + admission state + the weighted fair scheduler.

    Queue keys are opaque tuples whose LAST component is the tenant (the
    engines' ``_queue_key`` convention); the controller never inspects the
    rest. Scheduler state is the per-tenant lazy oldest-head heap (the same
    stale-entry discipline the pre-tenancy engine heap used) plus the
    virtual clocks of start-time fair queueing.
    """

    # admits between sweeps of quiescent per-tenant state (buckets that
    # have refilled to capacity, expired virtual-time debt, zero backlogs)
    SWEEP_EVERY = 4096

    def __init__(self, policies: Optional[Dict[str, TenantPolicy]] = None,
                 default_policy: Optional[TenantPolicy] = None,
                 staleness_bound_s: float = 1.0):
        self.default_policy = default_policy or TenantPolicy()
        self.staleness_bound_s = float(staleness_bound_s)
        self._policies: Dict[str, TenantPolicy] = dict(policies or {})
        self._buckets: Dict[str, _TokenBucket] = {}
        self._backlog: Dict[str, int] = {}
        # weighted virtual time: per-tenant finish tags + the global clock
        self._vtime: Dict[str, float] = {}
        self._vclock = 0.0
        # per-tenant lazy oldest-head heaps: (head t_submit, seq, key)
        self._heaps: Dict[str, List[Tuple[float, int, tuple]]] = {}
        self._seq = 0
        self._admits_since_sweep = 0
        # scheduling decision of the most recent pick() that returned a
        # queue — tenant, its virtual start tag, and whether the staleness
        # bound preempted the virtual-time order. Read by the engines (under
        # the same lock discipline as every other mutating call) to tag the
        # served batch's trace with WHY it was scheduled.
        self.last_pick: Optional[dict] = None

    # ------------------------------------------------------------ policy ----
    def policy(self, tenant: str) -> TenantPolicy:
        return self._policies.get(tenant, self.default_policy)

    def set_policy(self, tenant: str, policy: TenantPolicy) -> None:
        """Install (or replace) a tenant's policy; its token bucket restarts
        full at the new rate."""
        self._policies[tenant] = policy
        self._buckets.pop(tenant, None)

    def backlog(self, tenant: str) -> int:
        """Queries currently queued (not yet popped into a batch)."""
        return self._backlog.get(tenant, 0)

    # --------------------------------------------------------- admission ----
    def admit(self, tenant: str,
              now: Optional[float] = None) -> AdmissionDecision:
        """Decide one submission. Depth is checked before rate so a shed
        (overload) submission does not also burn a rate token."""
        now = time.perf_counter() if now is None else now
        self._admits_since_sweep += 1
        if self._admits_since_sweep >= self.SWEEP_EVERY:
            self._sweep(now)
        pol = self.policy(tenant)
        depth = self._backlog.get(tenant, 0)
        if pol.max_queue_depth is not None and depth >= pol.max_queue_depth:
            return AdmissionDecision(
                SHED, tenant,
                reason=f"queue depth {depth} at limit {pol.max_queue_depth}")
        bucket = self._buckets.get(tenant)
        if bucket is None:
            bucket = _TokenBucket(pol.rate_qps, pol.bucket_capacity, now)
            self._buckets[tenant] = bucket
        ok, retry = bucket.try_take(now)
        if not ok:
            return AdmissionDecision(
                THROTTLE, tenant, retry_after_s=retry,
                reason=f"rate limit {pol.rate_qps:g} qps exceeded")
        return AdmissionDecision(ACCEPT, tenant)

    def _sweep(self, now: float) -> None:
        """Drop quiescent per-tenant state, so high-cardinality tenant ids
        (per-user tags) don't grow the controller without bound. Buckets
        refilled to capacity and zero backlogs are exact state
        equivalences; pruning an IDLE tenant's virtual-time tag forgives
        at most its last batch / weight of residual debt — the standard
        fair-queueing semantics for a flow that drains and re-arrives
        (debt is only load-bearing while the tenant stays backlogged,
        which is exactly when its heap keeps the tag alive)."""
        self._admits_since_sweep = 0
        for t, b in list(self._buckets.items()):
            if math.isinf(b.rate) \
                    or b.tokens + (now - b.t_last) * b.rate >= b.capacity:
                del self._buckets[t]
        for t in list(self._vtime):
            if t not in self._heaps and self._backlog.get(t, 0) == 0:
                del self._vtime[t]
        for t in list(self._backlog):
            if self._backlog[t] == 0:
                del self._backlog[t]

    # --------------------------------------------------------- scheduler ----
    def on_enqueued(self, tenant: str) -> None:
        self._backlog[tenant] = self._backlog.get(tenant, 0) + 1

    def push_head(self, key: tuple, tenant: str, t_submit: float) -> None:
        """Record that ``key``'s queue (re)gained a head submitted at
        ``t_submit`` — the lazy-heap push of the pre-tenancy scheduler, now
        into the tenant's own heap."""
        self._seq += 1
        heapq.heappush(self._heaps.setdefault(tenant, []),
                       (t_submit, self._seq, key))

    def _peek(self, tenant: str, queues: Dict[tuple, Deque]
              ) -> Optional[Tuple[float, tuple]]:
        """Valid oldest head of one tenant's heap (lazy refresh: entries
        whose recorded head was served or reordered away are dropped and
        the live head re-pushed)."""
        heap = self._heaps.get(tenant)
        while heap:
            t, _, key = heap[0]
            dq = queues.get(key)
            if not dq:
                heapq.heappop(heap)
                continue
            if dq[0].t_submit != t:
                heapq.heappop(heap)
                self.push_head(key, tenant, dq[0].t_submit)
                continue
            return t, key
        # fully drained: drop the tenant's heap so pick() only ever scans
        # tenants with live backlog (push_head recreates it on demand)
        if heap is not None:
            del self._heaps[tenant]
        return None

    def pick(self, queues: Dict[tuple, Deque],
             now: Optional[float] = None) -> Optional[tuple]:
        """The queue to serve next.

        Overdue heads (waiting past ``staleness_bound_s``) win globally in
        FIFO order — the starvation bound. Otherwise the backlogged tenant
        with the smallest virtual start tag wins, ties broken by oldest
        head — which, with one tenant, IS the oldest-head pick of the
        pre-tenancy heap.

        Cost: O(#currently-backlogged tenants) per pick, each a lazy
        O(log #queues) peek (drained tenants leave the scan via the
        ``_peek`` prune). An incremental tenant-level structure — a heap
        over virtual start tags plus a global oldest-head tracker for the
        staleness override — is the open optimization if concurrently
        backlogged tenant counts grow past a few thousand.
        """
        now = time.perf_counter() if now is None else now
        best_key, best_rank = None, None
        overdue_key, overdue_t = None, math.inf
        for tenant in list(self._heaps):
            head = self._peek(tenant, queues)
            if head is None:
                continue
            t, key = head
            if now - t >= self.staleness_bound_s and t < overdue_t:
                overdue_key, overdue_t = key, t
            rank = (max(self._vtime.get(tenant, 0.0), self._vclock), t)
            if best_rank is None or rank < best_rank:
                best_key, best_rank = key, rank
        picked = best_key if overdue_key is None else overdue_key
        if picked is not None:
            tenant = picked[-1]
            self.last_pick = dict(
                tenant=tenant,
                vtime=max(self._vtime.get(tenant, 0.0), self._vclock),
                overdue=overdue_key is not None)
        return picked

    def on_served(self, tenant: str, n: int) -> None:
        """Account one popped batch of ``n`` queries: the tenant's virtual
        time advances by ``n / weight`` from its start tag (so a tenant
        with twice the weight pays half the virtual cost per query), and
        its queued backlog shrinks."""
        w = self.policy(tenant).weight
        start = max(self._vtime.get(tenant, 0.0), self._vclock)
        self._vclock = start
        self._vtime[tenant] = start + n / w
        self._backlog[tenant] = max(0, self._backlog.get(tenant, 0) - n)

    def on_requeued(self, tenant: str, n: int) -> None:
        """A popped batch bounced back to its queue (extract/compute
        failure path): restore the backlog accounting. The virtual-time
        charge of the failed service attempt deliberately stands — a
        tenant whose batches keep failing must not starve its neighbors by
        replaying at zero virtual cost."""
        self._backlog[tenant] = self._backlog.get(tenant, 0) + n
