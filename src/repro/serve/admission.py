"""Multi-tenant admission control + weighted fair scheduling for the GNN
serving engines.

Two concerns live here, both previously inlined (or absent) in
:class:`~repro.serve.gnn_engine.GNNServeEngine`:

  * **Admission** — every ``submit()`` is checked against the submitting
    tenant's :class:`TenantPolicy` BEFORE it touches a queue: a token bucket
    enforces the tenant's sustained rate (``rate_qps``, burst capacity
    ``burst``), and ``max_queue_depth`` bounds the tenant's queued backlog.
    The outcome is a typed :class:`AdmissionDecision` — ``accept`` /
    ``throttle`` (rate limit, with a ``retry_after_s`` hint) / ``shed``
    (overload) — attached to the returned query, NEVER an exception: one
    tenant blowing its quota must bounce back to that tenant's caller, not
    crash a tick that is also carrying other tenants' queries.

  * **Scheduling** — the engine's queue pick generalizes the lazy
    oldest-head heap to **weighted start-time fair queueing across
    tenants**: each tenant carries a virtual time that advances by
    ``cost / weight`` whenever one of its queues is served (``cost``
    defaulting to the batch size, or the batch's predicted cost units when
    the engine carries a :class:`~repro.serve.cost.CostEstimator`), and the
    pick goes to the backlogged tenant with the smallest virtual start tag
    (FIFO oldest-head WITHIN a tenant — with a single tenant this is
    exactly the pre-tenancy scheduler). Higher-weight tenants therefore
    drain proportionally faster under contention, while the **staleness
    bound** keeps the scheduler starvation-free: any queue head that has
    waited longer than ``staleness_bound_s`` preempts the virtual-time
    order and is served globally FIFO among the overdue — a weight-1 tenant
    behind a weight-100 firehose still sees every request picked within
    (roughly) the bound plus one batch service time.

The controller is NOT internally locked: the engine already serializes
queue surgery under its ``_qlock`` and calls every mutating method while
holding it.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from typing import Deque, Dict, List, Optional, Tuple

DEFAULT_TENANT = "default"

ACCEPT = "accept"
THROTTLE = "throttle"
SHED = "shed"


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Per-tenant serving contract.

    ``rate_qps``         sustained admission rate (token-bucket refill);
                         ``inf`` disables rate limiting.
    ``burst``            bucket capacity — how far above the sustained rate
                         a short spike may go; defaults to
                         ``max(1, rate_qps)`` (one second of traffic).
    ``weight``           scheduler share: under contention a tenant drains
                         proportionally to its weight (integer >= 1).
    ``max_queue_depth``  queued-backlog bound; submissions beyond it are
                         shed (``None`` = unbounded).
    ``cost_rate``        cost budget in predicted cost units per second
                         (``None`` disables cost charging): when the engine
                         carries a :class:`~repro.serve.cost.CostEstimator`,
                         a second token bucket charges each submission its
                         PREDICTED units instead of 1 — a tenant nominally
                         under its QPS limit but submitting hub-node whales
                         drains this bucket and is throttled on cost.
    ``cost_burst``       cost-bucket capacity; defaults to one second of
                         budget (``max(1, cost_rate)``).
    """
    rate_qps: float = math.inf
    burst: Optional[float] = None
    weight: int = 1
    max_queue_depth: Optional[int] = None
    cost_rate: Optional[float] = None
    cost_burst: Optional[float] = None

    def __post_init__(self):
        if not self.rate_qps > 0:
            raise ValueError(f"rate_qps must be > 0, got {self.rate_qps}")
        if self.burst is not None and not self.burst >= 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        if int(self.weight) != self.weight or self.weight < 1:
            raise ValueError(f"weight must be an integer >= 1, "
                             f"got {self.weight}")
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError(f"max_queue_depth must be >= 1, "
                             f"got {self.max_queue_depth}")
        if self.cost_rate is not None and not self.cost_rate > 0:
            raise ValueError(f"cost_rate must be > 0, got {self.cost_rate}")
        if self.cost_burst is not None and not self.cost_burst >= 1:
            raise ValueError(f"cost_burst must be >= 1, "
                             f"got {self.cost_burst}")

    @property
    def bucket_capacity(self) -> float:
        if self.burst is not None:
            return float(self.burst)
        return math.inf if math.isinf(self.rate_qps) \
            else max(1.0, self.rate_qps)

    @property
    def cost_bucket_capacity(self) -> float:
        if self.cost_burst is not None:
            return float(self.cost_burst)
        return math.inf if self.cost_rate is None \
            else max(1.0, self.cost_rate)


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """Typed outcome of one ``submit()`` admission check. ``cost`` is the
    predicted cost units the submission was charged (1.0 when no cost
    estimator is wired in)."""
    action: str                      # ACCEPT | THROTTLE | SHED
    tenant: str
    reason: str = ""
    retry_after_s: float = 0.0
    cost: float = 1.0

    @property
    def accepted(self) -> bool:
        return self.action == ACCEPT

    @property
    def cost_limited(self) -> bool:
        """Whether the cost-unit budget (not the QPS rate) throttled it."""
        return self.action == THROTTLE and self.reason.startswith("cost")


class _TokenBucket:
    """Continuous-refill token bucket. The admission rate bucket takes one
    token per query; the cost bucket charges predicted cost units."""

    __slots__ = ("rate", "capacity", "tokens", "t_last")

    def __init__(self, rate: float, capacity: float, now: float):
        self.rate = rate
        self.capacity = capacity
        self.tokens = capacity
        self.t_last = now

    def try_take(self, now: float, cost: float = 1.0) -> Tuple[bool, float]:
        """Take ``cost`` tokens; returns (ok, retry_after_s). The charge is
        clamped to the bucket capacity so a single whale beyond the burst
        needs a FULL bucket rather than being unadmittable forever."""
        if math.isinf(self.rate):
            return True, 0.0
        need = min(float(cost), self.capacity)
        self.tokens = min(self.capacity,
                          self.tokens + (now - self.t_last) * self.rate)
        self.t_last = now
        if self.tokens >= need:
            self.tokens -= need
            return True, 0.0
        return False, (need - self.tokens) / self.rate

    def refund(self, cost: float) -> None:
        self.tokens = min(self.capacity, self.tokens + float(cost))


class AdmissionController:
    """Tenant policies + admission state + the weighted fair scheduler.

    Queue keys are opaque tuples whose LAST component is the tenant (the
    engines' ``_queue_key`` convention); the controller never inspects the
    rest. Scheduler state is the per-tenant lazy oldest-head heap (the same
    stale-entry discipline the pre-tenancy engine heap used) plus the
    virtual clocks of start-time fair queueing.
    """

    # admits between sweeps of quiescent per-tenant state (buckets that
    # have refilled to capacity, expired virtual-time debt, zero backlogs)
    SWEEP_EVERY = 4096

    def __init__(self, policies: Optional[Dict[str, TenantPolicy]] = None,
                 default_policy: Optional[TenantPolicy] = None,
                 staleness_bound_s: float = 1.0):
        self.default_policy = default_policy or TenantPolicy()
        self.staleness_bound_s = float(staleness_bound_s)
        self._policies: Dict[str, TenantPolicy] = dict(policies or {})
        self._buckets: Dict[str, _TokenBucket] = {}
        self._cost_buckets: Dict[str, _TokenBucket] = {}
        self._backlog: Dict[str, int] = {}
        # SLO feedback: multiplier on a tenant's max_queue_depth (the
        # SLOTracker's autotune shrinks it under sustained budget burn)
        self._depth_scale: Dict[str, float] = {}
        # weighted virtual time: per-tenant finish tags + the global clock
        self._vtime: Dict[str, float] = {}
        self._vclock = 0.0
        # per-tenant lazy oldest-head heaps: (head t_submit, seq, key)
        self._heaps: Dict[str, List[Tuple[float, int, tuple]]] = {}
        # the incremental pick() structure (the heap-over-virtual-start-
        # tags refactor): a lazy min-heap of (virtual start, head t_submit,
        # seq, tenant) scheduling tags. Entries go stale (served heads,
        # advanced virtual clocks) and are corrected or dropped at pop
        # time; ranks only ever increase, so the lazy-min argument of the
        # pre-tenancy oldest-head heap carries over.
        self._tags: List[Tuple[float, float, int, str]] = []
        # tenants whose virtual time moved since the last pick (their tags
        # must be refreshed before the next pop)
        self._dirty: set = set()
        self._seq = 0
        self._admits_since_sweep = 0
        # scheduling decision of the most recent pick() that returned a
        # queue — tenant, its virtual start tag, and whether the staleness
        # bound preempted the virtual-time order. Read by the engines (under
        # the same lock discipline as every other mutating call) to tag the
        # served batch's trace with WHY it was scheduled.
        self.last_pick: Optional[dict] = None

    # ------------------------------------------------------------ policy ----
    def policy(self, tenant: str) -> TenantPolicy:
        return self._policies.get(tenant, self.default_policy)

    def spawn(self) -> "AdmissionController":
        """A fresh controller carrying the same policies/staleness bound but
        none of the runtime state (buckets, backlogs, virtual clocks). The
        replica tier uses this to give each engine generation — a resharded
        replacement, a rebuilt replica — its own controller serialized under
        its own ``_qlock`` while preserving the tenant contracts; sharing
        one controller across two live engines would race their locks."""
        return AdmissionController(policies=dict(self._policies),
                                   default_policy=self.default_policy,
                                   staleness_bound_s=self.staleness_bound_s)

    def set_policy(self, tenant: str, policy: TenantPolicy) -> None:
        """Install (or replace) a tenant's policy; its token buckets
        restart full at the new rates."""
        self._policies[tenant] = policy
        self._buckets.pop(tenant, None)
        self._cost_buckets.pop(tenant, None)

    def backlog(self, tenant: str) -> int:
        """Queries currently queued (not yet popped into a batch)."""
        return self._backlog.get(tenant, 0)

    # ------------------------------------------------------- SLO feedback ---
    def set_depth_scale(self, tenant: str, scale: float) -> None:
        """Install the SLO autotuner's multiplier on the tenant's
        ``max_queue_depth`` (clamped to (0, 1]; 1.0 clears the override)."""
        scale = min(max(float(scale), 1e-6), 1.0)
        if scale >= 1.0:
            self._depth_scale.pop(tenant, None)
        else:
            self._depth_scale[tenant] = scale

    def effective_depth(self, tenant: str) -> Optional[int]:
        """The tenant's depth bound after SLO feedback (None = unbounded)."""
        depth = self.policy(tenant).max_queue_depth
        if depth is None:
            return None
        return max(1, int(depth * self._depth_scale.get(tenant, 1.0)))

    # --------------------------------------------------------- admission ----
    def admit(self, tenant: str, now: Optional[float] = None,
              cost: float = 1.0) -> AdmissionDecision:
        """Decide one submission charged ``cost`` predicted units. Depth is
        checked before either bucket so a shed (overload) submission does
        not also burn tokens; the cost budget is checked before the QPS
        rate (and refunded on a rate throttle) so a rejected submission
        never burns both."""
        now = time.perf_counter() if now is None else now
        self._admits_since_sweep += 1
        if self._admits_since_sweep >= self.SWEEP_EVERY:
            self._sweep(now)
        pol = self.policy(tenant)
        depth = self._backlog.get(tenant, 0)
        limit = self.effective_depth(tenant)
        if limit is not None and depth >= limit:
            reason = f"queue depth {depth} at limit {limit}"
            if limit != pol.max_queue_depth:
                reason += f" (SLO-scaled from {pol.max_queue_depth})"
            return AdmissionDecision(SHED, tenant, reason=reason, cost=cost)
        cost_bucket = None
        if pol.cost_rate is not None:
            cost_bucket = self._cost_buckets.get(tenant)
            if cost_bucket is None:
                cost_bucket = _TokenBucket(pol.cost_rate,
                                           pol.cost_bucket_capacity, now)
                self._cost_buckets[tenant] = cost_bucket
            ok, retry = cost_bucket.try_take(now, cost)
            if not ok:
                return AdmissionDecision(
                    THROTTLE, tenant, retry_after_s=retry, cost=cost,
                    reason=f"cost budget {pol.cost_rate:g} units/s "
                           f"exceeded (charge {cost:g})")
        bucket = self._buckets.get(tenant)
        if bucket is None:
            bucket = _TokenBucket(pol.rate_qps, pol.bucket_capacity, now)
            self._buckets[tenant] = bucket
        ok, retry = bucket.try_take(now)
        if not ok:
            if cost_bucket is not None:
                cost_bucket.refund(cost)
            return AdmissionDecision(
                THROTTLE, tenant, retry_after_s=retry, cost=cost,
                reason=f"rate limit {pol.rate_qps:g} qps exceeded")
        return AdmissionDecision(ACCEPT, tenant, cost=cost)

    def _sweep(self, now: float) -> None:
        """Drop quiescent per-tenant state, so high-cardinality tenant ids
        (per-user tags) don't grow the controller without bound. Buckets
        refilled to capacity and zero backlogs are exact state
        equivalences; pruning an IDLE tenant's virtual-time tag forgives
        at most its last batch / weight of residual debt — the standard
        fair-queueing semantics for a flow that drains and re-arrives
        (debt is only load-bearing while the tenant stays backlogged,
        which is exactly when its heap keeps the tag alive)."""
        self._admits_since_sweep = 0
        for buckets in (self._buckets, self._cost_buckets):
            for t, b in list(buckets.items()):
                if math.isinf(b.rate) \
                        or b.tokens + (now - b.t_last) * b.rate >= b.capacity:
                    del buckets[t]
        for t in list(self._vtime):
            if t not in self._heaps and self._backlog.get(t, 0) == 0:
                del self._vtime[t]
        for t in list(self._backlog):
            if self._backlog[t] == 0:
                del self._backlog[t]

    # --------------------------------------------------------- scheduler ----
    def on_enqueued(self, tenant: str) -> None:
        self._backlog[tenant] = self._backlog.get(tenant, 0) + 1

    def push_head(self, key: tuple, tenant: str, t_submit: float) -> None:
        """Record that ``key``'s queue (re)gained a head submitted at
        ``t_submit`` — the lazy-heap push of the pre-tenancy scheduler, now
        into the tenant's own heap PLUS the incremental pick() structure:
        the tenant's scheduling tag."""
        self._seq += 1
        heapq.heappush(self._heaps.setdefault(tenant, []),
                       (t_submit, self._seq, key))
        heapq.heappush(self._tags,
                       (self._vstart(tenant), t_submit, self._seq, tenant))

    def _vstart(self, tenant: str) -> float:
        """The tenant's current virtual start tag."""
        return max(self._vtime.get(tenant, 0.0), self._vclock)

    def _peek(self, tenant: str, queues: Dict[tuple, Deque]
              ) -> Optional[Tuple[float, tuple]]:
        """Valid oldest head of one tenant's heap (lazy refresh: entries
        whose recorded head was served or reordered away are dropped and
        the live head re-pushed)."""
        heap = self._heaps.get(tenant)
        while heap:
            t, _, key = heap[0]
            dq = queues.get(key)
            if not dq:
                heapq.heappop(heap)
                continue
            if dq[0].t_submit != t:
                heapq.heappop(heap)
                self.push_head(key, tenant, dq[0].t_submit)
                continue
            return t, key
        # fully drained: drop the tenant's heap so pick() only ever scans
        # tenants with live backlog (push_head recreates it on demand)
        if heap is not None:
            del self._heaps[tenant]
        return None

    def _push_tag(self, tenant: str, queues: Dict[tuple, Deque]) -> None:
        """Refresh one tenant's scheduling tag after its virtual time moved
        (no-op for tenants with no live head)."""
        cur = self._peek(tenant, queues)
        if cur is None:
            return
        self._seq += 1
        heapq.heappush(self._tags,
                       (self._vstart(tenant), cur[0], self._seq, tenant))

    def pick(self, queues: Dict[tuple, Deque],
             now: Optional[float] = None) -> Optional[tuple]:
        """The queue to serve next.

        Overdue heads (waiting past ``staleness_bound_s``) win globally in
        FIFO order — the starvation bound. Otherwise the backlogged tenant
        with the smallest virtual start tag wins, ties broken by oldest
        head — which, with one tenant, IS the oldest-head pick of the
        pre-tenancy heap.

        Cost: the rank selection is O(log) amortized per pick via the
        lazy tag heap (instead of the previous O(#backlogged tenants)
        re-ranking scan with a heap peek per tenant). Lazy-min argument:
        virtual starts and head timestamps only ever increase
        (``on_served`` advances vtime; served heads are replaced by
        younger ones; ``on_requeued`` restores go back through
        ``push_head``, which pushes a fresh tag), so every backlogged
        tenant always owns at least one tag ranked <= its true rank —
        popping stale tags and re-pushing at most one corrected tag per
        tenant per pick cannot skip the minimum. The staleness watchdog
        stays a direct sweep of live queue heads (one float compare each):
        overdue-ness is a function of wall-clock NOW, not of any event the
        lazy structure could have witnessed.
        """
        now = time.perf_counter() if now is None else now
        horizon = now - self.staleness_bound_s
        overdue_key, overdue_t = None, math.inf
        for key, dq in queues.items():
            if dq and dq[0].t_submit <= horizon \
                    and dq[0].t_submit < overdue_t:
                overdue_key, overdue_t = key, dq[0].t_submit
        if overdue_key is not None:
            tenant = overdue_key[-1]
            self.last_pick = dict(tenant=tenant,
                                  vtime=self._vstart(tenant), overdue=True)
            return overdue_key
        for tenant in self._dirty:
            self._push_tag(tenant, queues)
        self._dirty.clear()
        fixed: set = set()
        heap = self._tags
        while heap:
            vstart, head_t, _, tenant = heap[0]
            cur = self._peek(tenant, queues)
            if cur is None:
                heapq.heappop(heap)
                continue
            true_vstart = self._vstart(tenant)
            if vstart != true_vstart or head_t != cur[0]:
                heapq.heappop(heap)
                if tenant not in fixed:
                    fixed.add(tenant)
                    self._seq += 1
                    heapq.heappush(
                        heap, (true_vstart, cur[0], self._seq, tenant))
                continue
            self.last_pick = dict(tenant=tenant, vtime=true_vstart,
                                  overdue=False)
            return cur[1]
        return None

    def on_served(self, tenant: str, n: int,
                  cost: Optional[float] = None) -> None:
        """Account one popped batch: the tenant's virtual time advances by
        ``cost / weight`` from its start tag — ``cost`` defaulting to the
        batch size ``n``, or the batch's summed PREDICTED cost units when
        the engine carries a cost estimator (so an expensive hub batch
        pushes its tenant further back in virtual time than a cheap
        full-cache batch of the same size) — and its queued backlog
        shrinks."""
        w = self.policy(tenant).weight
        start = max(self._vtime.get(tenant, 0.0), self._vclock)
        self._vclock = start
        charge = float(n if cost is None else cost)
        self._vtime[tenant] = start + charge / w
        self._backlog[tenant] = max(0, self._backlog.get(tenant, 0) - n)
        self._dirty.add(tenant)

    def on_requeued(self, tenant: str, n: int) -> None:
        """A popped batch bounced back to its queue (extract/compute
        failure path): restore the backlog accounting. The virtual-time
        charge of the failed service attempt deliberately stands — a
        tenant whose batches keep failing must not starve its neighbors by
        replaying at zero virtual cost."""
        self._backlog[tenant] = self._backlog.get(tenant, 0) + n

    def on_dequeued(self, tenant: str, n: int) -> None:
        """Queries left the queue WITHOUT being served here — evacuated to
        another replica, shed at drain timeout, or (at the front door)
        completed downstream. Backlog-only: no virtual-time charge, since
        no service happened on this controller's engine."""
        self._backlog[tenant] = max(0, self._backlog.get(tenant, 0) - n)
