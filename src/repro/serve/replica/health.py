"""Replica health protocol: heartbeats, deadline detection, recovery.

The front door beats every replica each :meth:`FrontDoor.tick`; a replica
whose beats stop arriving (killed, or its heartbeats are being injected
away) misses the :attr:`HealthPolicy.deadline_s` deadline and is marked
unhealthy — the front door then fails its in-flight work over to survivors.
Serving faults count too: ``fault_threshold`` consecutive stage errors on
one replica mark it unhealthy without waiting for the deadline (a replica
that answers heartbeats but can't serve is still down).

Recovery is symmetric: once an unhealthy replica's beats come back,
``recovery_beats`` consecutive good beats re-admit it (hysteresis — one
lucky beat from a flapping replica must not bounce traffic back).

All clock inputs are explicit (``now`` parameters): the monitor never reads
wall time itself, so tests drive it deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Detection/recovery knobs: miss a beat for ``deadline_s`` -> down;
    ``fault_threshold`` consecutive serve faults -> down; ``recovery_beats``
    consecutive good beats -> back up."""
    deadline_s: float = 0.25
    fault_threshold: int = 3
    recovery_beats: int = 2


@dataclasses.dataclass
class _ReplicaHealth:
    last_beat: float
    healthy: bool = True
    consecutive_faults: int = 0
    good_beats: int = 0
    missed_beats: int = 0
    transitions: int = 0            # healthy <-> unhealthy flips


class HealthMonitor:
    """Tracks per-replica liveness for the front door (see module doc)."""

    def __init__(self, policy: Optional[HealthPolicy] = None, tracer=None):
        self.policy = policy or HealthPolicy()
        self.tracer = tracer
        self._replicas: Dict[str, _ReplicaHealth] = {}

    def register(self, name: str, now: float) -> None:
        self._replicas[name] = _ReplicaHealth(last_beat=now)

    def healthy(self, name: str) -> bool:
        st = self._replicas.get(name)
        return st is not None and st.healthy

    def healthy_names(self) -> List[str]:
        return [n for n, st in self._replicas.items() if st.healthy]

    def _emit(self, event: str, name: str, **attrs) -> None:
        if self.tracer is not None:
            self.tracer.event(event, replica=name, **attrs)

    def _mark_down(self, name: str, reason: str, **attrs) -> None:
        st = self._replicas[name]
        if st.healthy:
            st.healthy = False
            st.transitions += 1
            self._emit("replica_unhealthy", name, reason=reason, **attrs)
        st.good_beats = 0

    # ------------------------------------------------------------ beats ----
    def beat(self, name: str, ok: bool, now: float) -> Optional[str]:
        """Fold one heartbeat result in. Returns ``"up"`` exactly when this
        beat completes an unhealthy replica's recovery (the front door
        re-admits it then), else None."""
        st = self._replicas[name]
        if not ok:
            st.missed_beats += 1
            st.good_beats = 0
            return None
        st.last_beat = now
        if st.healthy:
            return None
        st.good_beats += 1
        if st.good_beats >= self.policy.recovery_beats:
            st.healthy = True
            st.transitions += 1
            st.consecutive_faults = 0
            st.good_beats = 0
            self._emit("replica_recovered", name)
            return "up"
        return None

    def check(self, now: float) -> List[str]:
        """Deadline scan: replicas newly marked unhealthy because their
        last good beat is older than ``deadline_s``."""
        newly_down = []
        for name, st in self._replicas.items():
            if st.healthy and now - st.last_beat > self.policy.deadline_s:
                self._mark_down(name, "heartbeat deadline missed",
                                silent_s=now - st.last_beat)
                newly_down.append(name)
        return newly_down

    # ----------------------------------------------------- serve faults ----
    def fault(self, name: str, err: str, now: float) -> bool:
        """Fold one serving fault in; True when it crossed the consecutive
        threshold and newly marked the replica unhealthy."""
        st = self._replicas[name]
        st.consecutive_faults += 1
        if st.healthy and \
                st.consecutive_faults >= self.policy.fault_threshold:
            self._mark_down(name, "consecutive serve faults",
                            faults=st.consecutive_faults, error=err)
            return True
        return False

    def served(self, name: str) -> None:
        """A successful serve resets the consecutive-fault run."""
        st = self._replicas.get(name)
        if st is not None:
            st.consecutive_faults = 0

    def snapshot(self) -> dict:
        return {name: dict(healthy=st.healthy, last_beat=st.last_beat,
                           consecutive_faults=st.consecutive_faults,
                           missed_beats=st.missed_beats,
                           transitions=st.transitions)
                for name, st in sorted(self._replicas.items())}
