"""Fault-tolerant replica tier.

``router``  — :class:`FrontDoor` (global admission, feature-version
              pinning, tenant/query spread, failover resubmission) over
              :class:`ReplicaHandle` replicas; :func:`build_replica`.
``health``  — heartbeat protocol: deadline + consecutive-fault detection,
              hysteretic recovery (:class:`HealthMonitor`,
              :class:`HealthPolicy`).
``faults``  — deterministic chaos seam (:class:`FaultInjector`): seeded
              probabilistic/counted stage failures, replica kills,
              heartbeat drops, artifact corruption.
``reshard`` — live P -> P' repartition (:class:`Resharder`): background
              double-buffered build, artifact consistency gate, atomic
              intake swap + graceful drain.
"""
from .faults import FaultInjector, InjectedFault
from .health import HealthMonitor, HealthPolicy
from .reshard import Resharder, ReshardReport
from .router import FrontDoor, ReplicaHandle, RoutedQuery, build_replica

__all__ = [
    "FaultInjector", "InjectedFault", "HealthMonitor", "HealthPolicy",
    "Resharder", "ReshardReport", "FrontDoor", "ReplicaHandle",
    "RoutedQuery", "build_replica",
]
