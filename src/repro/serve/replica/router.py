"""Front-door routing over a tier of serving replicas.

A **replica** is one complete serving stack — its own
:class:`~repro.serve.gnn_session.GraphStore` (own sessions, own caches) plus
one engine — wrapped in a :class:`ReplicaHandle`. The :class:`FrontDoor`
owns what the replicas must agree on:

* **Admission** — ONE :class:`AdmissionController` at the front door makes
  every accept/throttle/shed decision (the per-replica engines run
  permissive default controllers), so a tenant's token budget is global
  across the tier instead of multiplying with the replica count.
* **Consistency pinning** — the front door tracks a per-graph feature
  version; every accepted query is pinned to the version current at submit
  and only routes to replicas whose store is AT that version. A feature
  update (:meth:`FrontDoor.update_features`) fans out to every replica and
  bumps the pin, so a query never mixes pre- and post-update features even
  while replicas converge.
* **Placement** — ``spread="tenant"`` routes each tenant to a stable
  replica by rendezvous hashing (cache affinity: one tenant's working set
  warms one replica); ``spread="query"`` round-robins individual queries
  (uniform load; chaos tests use it to guarantee the killed replica holds
  work).
* **Failover** — the :class:`~repro.serve.replica.health.HealthMonitor`
  watches heartbeats and serve faults; when a replica goes down the front
  door evacuates its accepted-but-unanswered queries (in service order) and
  resubmits them to surviving replicas at the same pinned version. A query
  whose replica dies is answered by a survivor — the submitting caller
  keeps polling the SAME :class:`RoutedQuery` and never learns the
  difference. When no survivor is eligible the queries park in an orphan
  list and re-dispatch as soon as a replica recovers.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from ..admission import (DEFAULT_TENANT, AdmissionController,
                         AdmissionDecision)
from ..gnn_engine import DrainReport, GNNServeEngine, NodeQuery
from ..gnn_session import GraphStore
from ..metrics import ServeMetrics
from ..trace import SpanTracer
from .health import HealthMonitor, HealthPolicy


class ReplicaHandle:
    """One replica: a name, its private store, and its serving engine.
    The engine can be atomically swapped (the live-reshard path) — new
    submits route to the new engine the instant :meth:`swap_engine`
    returns."""

    def __init__(self, name: str, store: GraphStore,
                 engine: GNNServeEngine):
        self.name = name
        self.store = store
        self.engine = engine
        engine.fault_scope = name

    def beat(self, now: float, faults=None) -> bool:
        """One heartbeat probe: False when the replica is (injected) dead
        or this beat was injected away."""
        if faults is not None:
            if faults.is_killed(self.name):
                return False
            if faults.take_heartbeat_drop(self.name):
                return False
        return True

    def graph_version(self, graph: str) -> int:
        return self.store.graphs[graph].version

    def swap_engine(self, new_engine: GNNServeEngine) -> GNNServeEngine:
        """Atomic intake redirect: returns the OLD engine (the caller
        drains it)."""
        old, self.engine = self.engine, new_engine
        new_engine.fault_scope = self.name
        return old


@dataclasses.dataclass
class RoutedQuery:
    """The front door's view of one query: the caller-facing object that
    survives failover. ``inner`` is the NodeQuery on whichever replica
    currently owns the work (re-pointed on failover); answers delegate to
    it, latency is measured from the FRONT DOOR submit."""
    graph: str
    model: str
    node: int
    tenant: str
    qid: int
    t_submit: float
    pinned_version: int
    replica: Optional[str] = None
    admission: Optional[AdmissionDecision] = None
    inner: Optional[NodeQuery] = None
    failovers: int = 0

    @property
    def done(self) -> bool:
        return self.inner is not None and self.inner.done

    @property
    def logits(self):
        return None if self.inner is None else self.inner.logits

    @property
    def pred(self):
        return None if self.inner is None else self.inner.pred

    @property
    def rejected(self) -> bool:
        return self.admission is not None and not self.admission.accepted

    @property
    def failed(self) -> bool:
        return self.inner is not None and (self.inner.failed
                                           or self.inner.rejected)

    @property
    def settled(self) -> bool:
        return self.rejected or self.done or self.failed

    @property
    def latency_s(self) -> float:
        if self.inner is None or not self.inner.t_done:
            return float("nan")
        return self.inner.t_done - self.t_submit


def _rendezvous(tenant: str, names: List[str]) -> List[str]:
    """Replica preference order for a tenant: highest-random-weight
    (rendezvous) hashing — stable under membership change (losing one
    replica only moves that replica's tenants)."""
    def w(name: str) -> int:
        h = hashlib.blake2b(f"{tenant}|{name}".encode(),
                            digest_size=8).digest()
        return int.from_bytes(h, "big")
    return sorted(names, key=w, reverse=True)


class FrontDoor:
    """Routes queries across replicas; owns admission, pinning, failover
    (see module docstring)."""

    def __init__(self, replicas: List[ReplicaHandle],
                 admission: Optional[AdmissionController] = None,
                 faults=None, tracer: Optional[SpanTracer] = None,
                 health: Optional[HealthMonitor] = None,
                 policy: Optional[HealthPolicy] = None,
                 spread: str = "tenant"):
        if not replicas:
            raise ValueError("need at least one replica")
        if spread not in ("tenant", "query"):
            raise ValueError(f"spread must be 'tenant' or 'query', "
                             f"got {spread!r}")
        self.replicas: Dict[str, ReplicaHandle] = {
            r.name: r for r in replicas}
        if len(self.replicas) != len(replicas):
            raise ValueError("replica names must be unique")
        self.admission = admission or AdmissionController()
        self.faults = faults
        self.tracer = tracer if tracer is not None else SpanTracer()
        self.health = health or HealthMonitor(policy, tracer=self.tracer)
        self.spread = spread
        self.metrics = ServeMetrics()
        now = time.perf_counter()
        for name in self.replicas:
            self.health.register(name, now)
        # per-graph feature version pin, seeded from the first replica
        # (every replica starts from the same registration sequence)
        first = replicas[0]
        self._versions: Dict[str, int] = {
            g: e.version for g, e in first.store.graphs.items()}
        self._next_qid = 0
        self._rr = 0                      # round-robin cursor (spread=query)
        self._live: Dict[str, List[RoutedQuery]] = {
            r.name: [] for r in replicas}
        self._orphans: deque = deque()    # accepted, no eligible replica yet
        self.finished: deque = deque(maxlen=100_000)   # settled RoutedQueries
        self.failovers = 0                # replica failover events
        self.failover_queries = 0         # queries moved by failovers
        self.readmissions = 0             # recovered replicas re-admitted

    # ----------------------------------------------------------- intake ----
    def _eligible(self, rq: RoutedQuery) -> List[str]:
        """Healthy replicas at the query's pinned feature version, in
        placement-preference order."""
        names = [n for n in self.health.healthy_names()
                 if self.replicas[n].graph_version(rq.graph)
                 == rq.pinned_version]
        if not names:
            return []
        if self.spread == "tenant":
            return _rendezvous(rq.tenant, names)
        names = sorted(names)
        self._rr += 1
        k = self._rr % len(names)
        return names[k:] + names[:k]

    def _dispatch(self, rq: RoutedQuery) -> bool:
        """Try to place ``rq`` on an eligible replica; False -> orphaned."""
        for name in self._eligible(rq):
            handle = self.replicas[name]
            inner = handle.engine.submit(rq.graph, rq.model, rq.node,
                                         tenant=rq.tenant)
            if inner.rejected:         # e.g. the replica is mid-drain
                continue
            rq.inner = inner
            rq.replica = name
            self._live[name].append(rq)
            return True
        return False

    def submit(self, graph: str, model: str, node: int,
               tenant: str = DEFAULT_TENANT) -> RoutedQuery:
        """Admit + route one query. Admission happens HERE, once — the
        outcome (typed decision) rides on the returned RoutedQuery exactly
        like the single-engine API. An accepted query with no eligible
        replica right now is NOT dropped: it parks as an orphan and
        dispatches as soon as a replica recovers or converges to its
        pinned version."""
        now = time.perf_counter()
        rq = RoutedQuery(graph=graph, model=model, node=int(node),
                         tenant=tenant, qid=self._next_qid, t_submit=now,
                         pinned_version=self._versions.get(graph, 0))
        self._next_qid += 1
        rq.admission = self.admission.admit(tenant, now)
        self.metrics.record_admission(tenant, rq.admission.action)
        if not rq.admission.accepted:
            return rq
        self.admission.on_enqueued(tenant)
        self.metrics.start_clock()
        if not self._dispatch(rq):
            self._orphans.append(rq)
        return rq

    def submit_many(self, graph: str, model: str, nodes,
                    tenant: str = DEFAULT_TENANT) -> List[RoutedQuery]:
        return [self.submit(graph, model, n, tenant=tenant)
                for n in np.asarray(nodes)]

    def update_features(self, graph: str, x: np.ndarray) -> None:
        """Fan a feature update out to EVERY replica, then bump the pin:
        queries submitted after this line route only to replicas that took
        the update (all of them, barring a concurrent failure — stragglers
        become ineligible rather than serving stale features)."""
        for handle in self.replicas.values():
            handle.store.update_features(graph, x)
        self._versions[graph] = \
            next(iter(self.replicas.values())).store.graphs[graph].version

    # --------------------------------------------------------- serving ----
    def _settle(self, rq: RoutedQuery) -> None:
        self.admission.on_dequeued(rq.tenant, 1)
        if rq.done:
            self.metrics.queries += 1
            self.metrics.latency.record(rq.latency_s)
            self.metrics.record_tenant_query(rq.tenant, rq.latency_s)
        self.finished.append(rq)

    def _failover(self, name: str) -> None:
        """Evacuate a down replica and move its accepted work to the
        survivors (orphaning what can't be placed)."""
        handle = self.replicas[name]
        moved = handle.engine.evacuate()
        by_qid = {rq.inner.qid: rq for rq in self._live[name]
                  if rq.inner is not None}
        self._live[name] = []
        relocated = orphaned = 0
        for q in moved:                     # evacuation (service) order
            rq = by_qid.get(q.qid)
            if rq is None or rq.settled:
                continue
            rq.failovers += 1
            rq.inner = None
            rq.replica = None
            if self._dispatch(rq):
                relocated += 1
            else:
                self._orphans.append(rq)
                orphaned += 1
        self.failovers += 1
        self.failover_queries += relocated + orphaned
        self.tracer.event("failover", replica=name, moved=len(moved),
                          relocated=relocated, orphaned=orphaned)

    def tick(self) -> int:
        """One supervision + serving round: heartbeat every replica, fail
        the newly-dead over, advance every healthy replica's engine one
        tick (a serving fault counts against its health), re-dispatch
        orphans, and settle finished queries. Returns queries answered."""
        now = time.perf_counter()
        for name, handle in self.replicas.items():
            ok = handle.beat(now, self.faults)
            went_up = self.health.beat(name, ok, now)
            if went_up == "up":
                handle.engine.resume_intake()
                self.readmissions += 1
        for name in self.health.check(now):
            self._failover(name)
        answered = 0
        for name, handle in self.replicas.items():
            if not self.health.healthy(name):
                continue
            if self.faults is not None and self.faults.is_killed(name):
                continue                    # dead replicas don't serve
            try:
                n = handle.engine.tick()
            except Exception as e:
                if self.health.fault(name, repr(e), time.perf_counter()):
                    self._failover(name)
                continue
            if n:
                answered += n
                self.health.served(name)
        # orphan re-dispatch: a recovered/converged replica picks them up
        for _ in range(len(self._orphans)):
            rq = self._orphans.popleft()
            if rq.settled:
                self._settle(rq)
                continue
            if not self._dispatch(rq):
                self._orphans.append(rq)
        # settle finished queries out of the live lists
        for name, live in self._live.items():
            keep = []
            for rq in live:
                if rq.settled:
                    self._settle(rq)
                else:
                    keep.append(rq)
            self._live[name] = keep
        return answered

    @property
    def pending(self) -> int:
        """Accepted queries not yet settled, tier-wide."""
        return (sum(len(v) for v in self._live.values())
                + len(self._orphans))

    def run_until_drained(self, max_ticks: int = 100_000
                          ) -> List[RoutedQuery]:
        """Tick until every accepted query settles (or the tick budget
        runs out — orphans with no recovering replica can wait forever;
        the budget turns that into a visible test failure)."""
        ticks = 0
        while self.pending and ticks < max_ticks:
            self.tick()
            ticks += 1
        self.metrics.stop_clock()
        return list(self.finished)

    def drain(self, timeout_s: float = 30.0) -> Dict[str, DrainReport]:
        """Graceful tier drain: stop intake and flush every healthy
        replica (per-replica :meth:`GNNServeEngine.drain` reports keyed by
        replica name)."""
        reports = {}
        for name, handle in self.replicas.items():
            if self.faults is not None and self.faults.is_killed(name):
                continue
            reports[name] = handle.engine.drain(timeout_s)
        # settle whatever the drains answered
        self.tick()
        self.metrics.stop_clock()
        return reports

    def reshard(self, name: str, graph: str, model: str, to_shards: int,
                artifact_dir=None, drain_timeout_s: float = 30.0):
        """Live-reshard one replica to ``to_shards`` (convenience wrapper
        around :class:`~repro.serve.replica.reshard.Resharder`: prepare in
        the background state, then swap + drain)."""
        from .reshard import Resharder
        rs = Resharder(self.replicas[name], graph, model, to_shards,
                       artifact_dir=artifact_dir,
                       drain_timeout_s=drain_timeout_s, tracer=self.tracer)
        rs.prepare(block=True)
        return rs.swap()

    def snapshot(self) -> dict:
        return dict(
            replicas=sorted(self.replicas),
            health=self.health.snapshot(),
            pending=self.pending, orphans=len(self._orphans),
            failovers=self.failovers,
            failover_queries=self.failover_queries,
            readmissions=self.readmissions,
            versions=dict(self._versions),
            metrics=self.metrics.snapshot(),
            faults=None if self.faults is None else self.faults.snapshot())


def build_replica(name: str, data, models: Dict[str, tuple],
                  n_shards: int = 0, cache_dir=None, graph: str = "g",
                  store_kw: Optional[dict] = None, faults=None,
                  tracer=None, **engine_kw) -> ReplicaHandle:
    """Stand one replica up: a private GraphStore with ``data`` registered
    as ``graph`` and each ``models[name] = (family, params)`` entry
    registered, plus a sharded engine (``n_shards >= 1``) or a single-host
    engine (``n_shards = 0``) over it."""
    from ..sharded import ShardedServeEngine
    store = GraphStore(cache_dir=str(cache_dir) if cache_dir else None,
                       **(store_kw or {}))
    store.register_graph(graph, data)
    for mname, (family, params) in models.items():
        store.register_model(mname, family, params)
    if n_shards >= 1:
        engine = ShardedServeEngine(store, n_shards, faults=faults,
                                    tracer=tracer, **engine_kw)
    else:
        engine = GNNServeEngine(store, faults=faults, tracer=tracer,
                                **engine_kw)
    return ReplicaHandle(name, store, engine)
