"""Deterministic fault injection for the replica tier.

One :class:`FaultInjector` is shared by every component under test: engines
consult it at their extract/launch/complete stage boundaries (via the
``faults=`` seam on :class:`~repro.serve.gnn_engine.GNNServeEngine`), replica
handles consult it in their heartbeat path, and the artifact robustness
tests use :meth:`corrupt_artifact` to damage checkpoint files on disk. All
randomness comes from one seeded generator and every mutating call happens
under one lock, so a chaos test replays identically run-to-run.

Two rule flavors per operation:

* :meth:`fail` — probabilistic: every matching :meth:`check` fails with the
  given rate (rate 1.0 = always, until :meth:`clear`).
* :meth:`fail_next` — counted: exactly the next ``n`` matching checks fail,
  then the rule disarms itself. The workhorse of deterministic tests.

``scope`` narrows a rule to one engine: the replica tier stamps each
engine's ``fault_scope`` with its replica name, so ``fail("launch",
scope="r1")`` only trips replica r1's launches. A rule with ``scope=None``
matches every engine.
"""
from __future__ import annotations

import threading
from pathlib import Path
from typing import Dict, Optional, Tuple

import numpy as np

OPS = ("extract", "launch", "complete")


class InjectedFault(RuntimeError):
    """A deliberately injected failure (chaos testing). Engines treat it
    exactly like a real stage error: requeue + bounded retry."""

    def __init__(self, op: str, scope: Optional[str] = None):
        self.op = op
        self.scope = scope
        where = f" on {scope!r}" if scope else ""
        super().__init__(f"injected {op} fault{where}")


class FaultInjector:
    """Seeded, lockable registry of failure rules (see module docstring)."""

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        # (op, scope) -> failure probability [probabilistic rules]
        self._rates: Dict[Tuple[str, Optional[str]], float] = {}
        # (op, scope) -> remaining forced failures [counted rules]
        self._counts: Dict[Tuple[str, Optional[str]], int] = {}
        # replicas currently killed (their heartbeat path reports dead)
        self._killed: set = set()
        # replica -> heartbeats still to swallow (drop without killing)
        self._beat_drops: Dict[str, int] = {}
        self._fired: Dict[str, int] = {}

    # ------------------------------------------------------------ rules ----
    def fail(self, op: str, rate: float = 1.0,
             scope: Optional[str] = None) -> None:
        """Fail matching checks with probability ``rate`` until cleared."""
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; have {OPS}")
        with self._lock:
            self._rates[(op, scope)] = float(rate)

    def fail_next(self, op: str, n: int = 1,
                  scope: Optional[str] = None) -> None:
        """Fail exactly the next ``n`` matching checks, then disarm."""
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; have {OPS}")
        with self._lock:
            self._counts[(op, scope)] = \
                self._counts.get((op, scope), 0) + int(n)

    def clear(self, op: Optional[str] = None) -> None:
        """Drop every rule for ``op`` (all ops when None). Kills and
        heartbeat drops are separate state (see :meth:`revive`)."""
        with self._lock:
            if op is None:
                self._rates.clear()
                self._counts.clear()
            else:
                for d in (self._rates, self._counts):
                    for k in [k for k in d if k[0] == op]:
                        del d[k]

    # ------------------------------------------------------------ check ----
    def check(self, op: str, scope: Optional[str] = None) -> None:
        """Stage-boundary hook: raise :class:`InjectedFault` when a rule
        matches ``op`` for this engine's ``scope`` (scoped rules first,
        then global ones)."""
        with self._lock:
            for key in ((op, scope), (op, None)):
                if self._counts.get(key, 0) > 0:
                    self._counts[key] -= 1
                    self._fired[op] = self._fired.get(op, 0) + 1
                    raise InjectedFault(op, scope)
                rate = self._rates.get(key)
                if rate is not None and self._rng.random() < rate:
                    self._fired[op] = self._fired.get(op, 0) + 1
                    raise InjectedFault(op, scope)

    # --------------------------------------------------- replica chaos ----
    def kill(self, name: str) -> None:
        """Hard-kill replica ``name``: its heartbeat path reports dead
        until :meth:`revive`."""
        with self._lock:
            self._killed.add(name)

    def revive(self, name: str) -> None:
        with self._lock:
            self._killed.discard(name)

    def is_killed(self, name: str) -> bool:
        with self._lock:
            return name in self._killed

    def drop_heartbeats(self, name: str, n: int = 1) -> None:
        """Swallow the next ``n`` heartbeats from ``name`` WITHOUT killing
        it — a replica that looks dead but isn't (the health monitor must
        still fail it over, and recovery must re-admit it)."""
        with self._lock:
            self._beat_drops[name] = self._beat_drops.get(name, 0) + int(n)

    def take_heartbeat_drop(self, name: str) -> bool:
        """Consume one pending heartbeat drop for ``name`` (True = this
        beat is swallowed)."""
        with self._lock:
            left = self._beat_drops.get(name, 0)
            if left <= 0:
                return False
            self._beat_drops[name] = left - 1
            return True

    # -------------------------------------------------------- artifacts ----
    def corrupt_artifact(self, path, keep_bytes: Optional[int] = None
                         ) -> Path:
        """Byte-truncate an on-disk artifact (default: cut it in half) —
        the checkpoint-robustness chaos: the next load must raise a typed
        ``ArtifactError`` naming this file, never a bare parser error."""
        path = Path(path)
        data = path.read_bytes()
        if keep_bytes is None:
            keep_bytes = len(data) // 2
        path.write_bytes(data[:max(0, int(keep_bytes))])
        return path

    def snapshot(self) -> dict:
        with self._lock:
            return dict(
                rates={f"{op}@{scope or '*'}": r
                       for (op, scope), r in self._rates.items()},
                counts={f"{op}@{scope or '*'}": c
                        for (op, scope), c in self._counts.items() if c},
                killed=sorted(self._killed),
                beat_drops=dict(self._beat_drops),
                fired=dict(self._fired))
