"""Live reshard: repartition one replica P -> P' without dropping queries.

The sequence the :class:`Resharder` drives:

1. **Persist** — save the replica's current sharded session to the artifact
   directory (checkpointer shards + ``routing.json`` sidecar), exactly the
   artifacts a cold start would restore from.
2. **Verify** — read the sidecar back through the typed loader and check
   its fingerprint against the LIVE store: a reshard must never proceed
   from artifacts that describe a different graph/model than the one
   serving traffic (a stale artifact directory raises ``ArtifactError``
   before any traffic moves).
3. **Build** — compile the P' session in the background (double-buffered:
   the old engine keeps serving the whole time), spin a new engine over it
   with the old engine's own ``engine_config()`` (same admission policies,
   tracer ring, retry discipline, chaos seam), and warm its shape buckets
   so the swapped-in engine serves with zero steady-state recompiles.
4. **Validate** — the old and new routing tables must contiguously cover
   the same node id space (:func:`~repro.serve.sharded.planner
   .validate_reshard`).
5. **Swap** — atomically redirect the replica's intake to the new engine,
   then drain the old one: its backlog and in-flight batches finish on the
   OLD partitioning (both partitionings are bit-exact, so answers don't
   care), and the drain report proves nothing was lost.

Bit-exactness falls out of the sharded session's core guarantee (any P
produces identical answers), which the chaos tests assert end-to-end:
a reshard under load yields the same logits as a freshly built P' stack.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from pathlib import Path
from typing import Optional

from .. import session_core
from ..gnn_engine import DrainReport
from ..sharded.planner import validate_reshard
from ..sharded.routing import RoutingTable
from .router import ReplicaHandle


@dataclasses.dataclass
class ReshardReport:
    """Outcome of one completed reshard swap."""
    replica: str
    graph: str
    model: str
    from_shards: int
    to_shards: int
    prepare_s: float
    swap_s: float
    drain: DrainReport

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["drain"] = self.drain.to_json()
        return d


class Resharder:
    """Background build + atomic swap of one replica's shard count."""

    def __init__(self, handle: ReplicaHandle, graph: str, model: str,
                 to_shards: int, artifact_dir=None,
                 drain_timeout_s: float = 30.0, tracer=None):
        if to_shards < 1:
            raise ValueError(f"to_shards must be >= 1, got {to_shards}")
        self.handle = handle
        self.graph = graph
        self.model = model
        self.to_shards = int(to_shards)
        self.artifact_dir = Path(artifact_dir) if artifact_dir else None
        self.drain_timeout_s = float(drain_timeout_s)
        self.tracer = tracer
        self._new_engine = None
        self._old_routing: Optional[RoutingTable] = None
        self._prepare_s = 0.0
        self._error: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------- prepare ----
    def _emit(self, name: str, **attrs) -> None:
        if self.tracer is not None:
            self.tracer.event(name, **attrs)

    def _prepare(self) -> None:
        t0 = time.perf_counter()
        old_engine = self.handle.engine
        store = self.handle.store
        from_shards = getattr(old_engine, "n_shards", 0)
        old_session = store.sharded_session(
            self.graph, self.model, from_shards,
            mesh=getattr(old_engine, "mesh", None),
            executor=getattr(old_engine, "executor", "host"),
            bn_mode=getattr(old_engine, "bn_mode", "single_host")) \
            if from_shards >= 1 else None
        if old_session is None:
            raise ValueError(
                f"replica {self.handle.name!r} is not sharded "
                f"(n_shards={from_shards}); reshard needs a sharded engine")
        self._old_routing = old_session.routing
        # 1. persist the live partitioning + 2. verify the artifacts read
        # back consistent with the store we are about to repartition
        if self.artifact_dir is not None:
            sess_dir = self.artifact_dir / (
                f"{self.graph}__{self.model}__P{from_shards}")
            old_session.save(sess_dir)
            sidecar = session_core.load_sidecar(
                sess_dir / "routing.json",
                required=("fingerprint", "routing", "n_shards"))
            if sidecar is None:
                raise session_core.ArtifactError(
                    sess_dir / "routing.json",
                    detail="reshard artifacts unreadable after save")
            live_fp = old_session.fingerprint()
            if sidecar["fingerprint"] != live_fp:
                raise session_core.ArtifactError(
                    sess_dir / "routing.json", field="fingerprint",
                    detail="artifact describes a different graph/model "
                           "than the live store")
        # 3. build the P' session + engine in the background (the old
        # engine keeps serving off its own session the whole time)
        new_session = store.sharded_session(
            self.graph, self.model, self.to_shards,
            mesh=getattr(old_engine, "mesh", None),
            executor=getattr(old_engine, "executor", "host"),
            bn_mode=getattr(old_engine, "bn_mode", "single_host"))
        # 4. routing-cover validation before any traffic moves
        validate_reshard(self._old_routing, new_session.routing,
                         store.graphs[self.graph].data.n_nodes)
        cfg = old_engine.engine_config()
        new_engine = type(old_engine)(store, self.to_shards, **cfg)
        new_engine.warmup(self.graph, self.model)
        self._new_engine = new_engine
        self._prepare_s = time.perf_counter() - t0
        self._emit("reshard", phase="prepared", replica=self.handle.name,
                   from_shards=from_shards, to_shards=self.to_shards,
                   prepare_s=self._prepare_s)

    def prepare(self, block: bool = True) -> "Resharder":
        """Build the P' stack. ``block=False`` runs it on a background
        thread (poll :attr:`ready`); errors surface at :meth:`swap`."""
        if block:
            self._prepare()
            return self

        def run():
            try:
                self._prepare()
            except BaseException as e:
                self._error = e
        self._thread = threading.Thread(target=run, daemon=True,
                                        name="resharder")
        self._thread.start()
        return self

    @property
    def ready(self) -> bool:
        return self._new_engine is not None or self._error is not None

    # ------------------------------------------------------------- swap ----
    def swap(self) -> ReshardReport:
        """Atomically redirect intake to the P' engine, drain the old one
        (its queued/in-flight work completes on the old partitioning), and
        shut it down. Returns the report; raises whatever a background
        :meth:`prepare` raised."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            raise self._error
        if self._new_engine is None:
            raise RuntimeError("swap() before prepare()")
        old_engine = self.handle.engine
        from_shards = getattr(old_engine, "n_shards", 0)
        t0 = time.perf_counter()
        self._emit("reshard", phase="swap_begin", replica=self.handle.name,
                   from_shards=from_shards, to_shards=self.to_shards)
        old = self.handle.swap_engine(self._new_engine)
        report = old.drain(self.drain_timeout_s)
        old.close()
        swap_s = time.perf_counter() - t0
        self._emit("reshard", phase="swap_end", replica=self.handle.name,
                   from_shards=from_shards, to_shards=self.to_shards,
                   swap_s=swap_s, drained=report.to_json())
        return ReshardReport(
            replica=self.handle.name, graph=self.graph, model=self.model,
            from_shards=from_shards, to_shards=self.to_shards,
            prepare_s=self._prepare_s, swap_s=swap_s, drain=report)
