"""Serving subsystem.

``engine``       — transformer continuous-batching serve loop (LLM path).
``session_core`` — shared compile/calibrate/bucketed-serve machinery,
                   including the PreparedBatch extract-stage objects.
``gnn_engine``   — micro-batched node-query engine over compiled sessions:
                   two-stage extract/compute pipeline (``pipeline_depth``),
                   heap-based oldest-head scheduling.
``gnn_session``  — GraphStore / CompiledGraphSession artifacts (GNN path).
``sharded``      — partitioned sessions: cross-shard k-hop routing + halo
                   exchange, halo-aware batch formation
                   (ShardedGraphSession / ShardedServeEngine).
``metrics``      — latency percentiles / QPS / cache counters + the
                   extract/compute breakdown and overlap-ratio gauge.
"""
from .gnn_engine import GNNServeEngine, NodeQuery
from .gnn_session import CompiledGraphSession, GraphStore, SessionPlan
from .metrics import LatencyStats, ServeMetrics
from .sharded import (ShardedGraphSession, ShardedServeEngine, ShardPlan,
                      ShardPlanner)

__all__ = [
    "GNNServeEngine", "NodeQuery", "CompiledGraphSession", "GraphStore",
    "SessionPlan", "LatencyStats", "ServeMetrics", "ShardedGraphSession",
    "ShardedServeEngine", "ShardPlan", "ShardPlanner",
]
