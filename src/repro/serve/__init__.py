"""Serving subsystem.

``engine``       — transformer continuous-batching serve loop (LLM path).
``session_core`` — shared compile/calibrate/bucketed-serve machinery.
``gnn_session``  — GraphStore / CompiledGraphSession artifacts (GNN path).
``gnn_engine``   — micro-batched node-query engine over compiled sessions.
``sharded``      — partitioned sessions: cross-shard k-hop routing + halo
                   exchange (ShardedGraphSession / ShardedServeEngine).
``metrics``      — latency percentiles / QPS / cache counters.
"""
from .gnn_engine import GNNServeEngine, NodeQuery
from .gnn_session import CompiledGraphSession, GraphStore, SessionPlan
from .metrics import LatencyStats, ServeMetrics
from .sharded import (ShardedGraphSession, ShardedServeEngine, ShardPlan,
                      ShardPlanner)

__all__ = [
    "GNNServeEngine", "NodeQuery", "CompiledGraphSession", "GraphStore",
    "SessionPlan", "LatencyStats", "ServeMetrics", "ShardedGraphSession",
    "ShardedServeEngine", "ShardPlan", "ShardPlanner",
]
