"""Serving subsystem.

``engine``      — transformer continuous-batching serve loop (LLM path).
``gnn_session`` — GraphStore / CompiledGraphSession artifacts (GNN path).
``gnn_engine``  — micro-batched node-query engine over compiled sessions.
``metrics``     — latency percentiles / QPS / cache counters.
"""
from .gnn_engine import GNNServeEngine, NodeQuery
from .gnn_session import CompiledGraphSession, GraphStore, SessionPlan
from .metrics import LatencyStats, ServeMetrics

__all__ = [
    "GNNServeEngine", "NodeQuery", "CompiledGraphSession", "GraphStore",
    "SessionPlan", "LatencyStats", "ServeMetrics",
]
