"""Serving subsystem.

``engine``       — transformer continuous-batching serve loop (LLM path).
``session_core`` — shared compile/calibrate/bucketed-serve machinery,
                   including the PreparedBatch extract-stage objects.
``admission``    — multi-tenant admission control (TenantPolicy token
                   buckets, typed accept/throttle/shed decisions) + the
                   weighted virtual-time scheduler of the engines.
``gnn_engine``   — micro-batched node-query engine over compiled sessions:
                   two-stage extract/compute pipeline (``pipeline_depth``),
                   tenant-aware weighted fair scheduling.
``gnn_session``  — GraphStore / CompiledGraphSession artifacts (GNN path).
``sharded``      — partitioned sessions: cross-shard k-hop routing + halo
                   exchange, halo-aware batch formation
                   (ShardedGraphSession / ShardedServeEngine).
``metrics``      — latency percentiles / QPS / cache counters + the
                   extract/compute breakdown, overlap-ratio gauge, and
                   per-tenant admission/latency breakdowns.
"""
from .admission import (AdmissionController, AdmissionDecision,
                        DEFAULT_TENANT, TenantPolicy)
from .gnn_engine import GNNServeEngine, NodeQuery
from .gnn_session import CompiledGraphSession, GraphStore, SessionPlan
from .metrics import LatencyStats, ServeMetrics, TenantMetrics
from .sharded import (ShardedGraphSession, ShardedServeEngine, ShardPlan,
                      ShardPlanner)

__all__ = [
    "AdmissionController", "AdmissionDecision", "DEFAULT_TENANT",
    "TenantPolicy", "GNNServeEngine", "NodeQuery", "CompiledGraphSession",
    "GraphStore", "SessionPlan", "LatencyStats", "ServeMetrics",
    "TenantMetrics", "ShardedGraphSession", "ShardedServeEngine",
    "ShardPlan", "ShardPlanner",
]
