"""Serving subsystem.

``adapters``     — ModelFamilyAdapter seam: GNNAdapter + TokenAdapter
                   implement quantize / traced serve body / bucket shaping
                   / state extraction per family; the core stays generic.
``session_core`` — shared compile/calibrate/bucketed-serve machinery,
                   including the PreparedBatch extract-stage objects.
``token_session``— TokenSession / TokenStore: chunked autoregressive
                   decode over the serving core (binary transformer +
                   SSM), pow2-bucketed cache lengths.
``token_engine`` — TokenServeEngine: the LLM decode path on the same
                   scheduler as the GNN engines (admission, cost, spans).
``engine``       — DEPRECATED compatibility shim over ``token_session``.
``admission``    — multi-tenant admission control (TenantPolicy token
                   buckets, typed accept/throttle/shed decisions) + the
                   weighted virtual-time scheduler of the engines.
``gnn_engine``   — micro-batched node-query engine over compiled sessions:
                   two-stage extract/compute pipeline (``pipeline_depth``),
                   tenant-aware weighted fair scheduling.
``gnn_session``  — GraphStore / CompiledGraphSession artifacts (GNN path).
``sharded``      — partitioned sessions: cross-shard k-hop routing + halo
                   exchange, halo-aware batch formation
                   (ShardedGraphSession / ShardedServeEngine).
``metrics``      — latency percentiles / QPS / cache counters + the
                   extract/compute breakdown, overlap-ratio gauge, and
                   per-tenant admission/latency breakdowns.
``trace``        — per-batch span tracing (SpanTracer ring buffer, sampled
                   steady state + always-on outlier/error capture) and the
                   recompile/transfer watchdogs.
``export``       — offline exporters over the trace ring buffer:
                   Chrome-trace JSON (Perfetto) + Prometheus text.
``cost``         — submit-time per-query cost prediction (k-hop closure /
                   halo / padding statics) + online calibration against
                   measured batch time and pro-rata attribution.
``slo``          — per-tenant SLO policies: error-budget burn-rate
                   tracking, multi-window alerts, admission-depth
                   feedback.
``replica``      — fault-tolerant replica tier: FrontDoor routing with
                   global admission + feature-version pinning,
                   health-checked failover, deterministic fault injection,
                   live reshard (see ``repro.serve.replica``).
"""
from .adapters import GNNAdapter, ModelFamilyAdapter, TokenAdapter
from .admission import (AdmissionController, AdmissionDecision,
                        DEFAULT_TENANT, TenantPolicy)
from .cost import CostEstimate, CostEstimator, spearman_rho
from .export import chrome_trace, prometheus_text, write_chrome_trace
from .gnn_engine import (DrainReport, GNNServeEngine, NodeQuery,
                         QueryFailure)
from .slo import SLOPolicy, SLOTracker
from .gnn_session import CompiledGraphSession, GraphStore, SessionPlan
from .metrics import LatencyStats, ServeMetrics, TenantMetrics
from .session_core import ArtifactError
from .sharded import (ShardedGraphSession, ShardedServeEngine, ShardPlan,
                      ShardPlanner)
from .token_engine import TokenQuery, TokenServeEngine
from .token_session import TokenPreparedBatch, TokenSession, TokenStore
from .trace import (BatchTrace, RecompileWatchdog, SpanTracer,
                    TransferWatchdog, WarningEvent)
from .replica import (FaultInjector, FrontDoor, HealthMonitor,
                      HealthPolicy, InjectedFault, ReplicaHandle,
                      Resharder, ReshardReport, RoutedQuery, build_replica)

__all__ = [
    "AdmissionController", "AdmissionDecision", "DEFAULT_TENANT",
    "TenantPolicy", "GNNServeEngine", "NodeQuery", "CompiledGraphSession",
    "GraphStore", "SessionPlan", "LatencyStats", "ServeMetrics",
    "TenantMetrics", "ShardedGraphSession", "ShardedServeEngine",
    "ShardPlan", "ShardPlanner", "BatchTrace", "SpanTracer",
    "RecompileWatchdog", "TransferWatchdog", "WarningEvent",
    "chrome_trace", "prometheus_text", "write_chrome_trace",
    "CostEstimate", "CostEstimator", "spearman_rho",
    "SLOPolicy", "SLOTracker",
    "ArtifactError", "DrainReport", "QueryFailure",
    "ModelFamilyAdapter", "GNNAdapter", "TokenAdapter",
    "TokenSession", "TokenStore", "TokenPreparedBatch",
    "TokenServeEngine", "TokenQuery",
    "FaultInjector", "InjectedFault", "FrontDoor", "ReplicaHandle",
    "RoutedQuery", "build_replica", "HealthMonitor", "HealthPolicy",
    "Resharder", "ReshardReport",
]
