"""Offline exporters over the :class:`~repro.serve.trace.SpanTracer` ring
buffer: Chrome-trace JSON (``chrome://tracing`` / Perfetto loadable) and a
Prometheus text-exposition snapshot.

Both are pure functions of already-recorded data — nothing here runs in the
serving hot path. The Chrome trace lays out one PROCESS per shard (plus one
for unsharded batches) and one THREAD per pipeline stage, so Perfetto's
timeline shows queue-wait / extract / launch / compute as parallel tracks
and the PR 4 extract/compute overlap is visible as literal span overlap.
"""
from __future__ import annotations

import json
from typing import Iterable, List, Optional

from .trace import STAGES, BatchTrace, SpanTracer, WarningEvent

_US = 1e6  # chrome trace timestamps are microseconds


def _pid_of(tr: BatchTrace) -> int:
    # pid 1 = the unsharded engine; shard i gets pid 2+i
    return 1 if tr.shard is None else 2 + int(tr.shard)


def _pid_name(pid: int) -> str:
    return "serve" if pid == 1 else f"shard-{pid - 2}"


def chrome_trace(source) -> dict:
    """Build a Chrome-trace object (``json.dump`` it to a file and load in
    Perfetto) from a :class:`SpanTracer` or an iterable of trace records.

    Batch spans become "X" (complete) duration events on a (pid=shard,
    tid=stage) track; watchdog warnings become instant "i" events on a
    dedicated track. Timestamps are rebased to the earliest span so the
    viewer opens at t=0."""
    if isinstance(source, SpanTracer):
        records = source.records()
    else:
        records = list(source)
    batches = [r for r in records if isinstance(r, BatchTrace)]
    warnings = [r for r in records if isinstance(r, WarningEvent)]

    t0s = [s.t0 for tr in batches for s in tr.spans]
    t0s += [w.t for w in warnings]
    base = min(t0s) if t0s else 0.0

    events: List[dict] = []
    pids = {}
    for tr in batches:
        pid = _pid_of(tr)
        pids.setdefault(pid, _pid_name(pid))
        common = dict(trace_id=tr.trace_id, key=list(tr.key),
                      tenant=tr.tenant, n_queries=len(tr.queries),
                      kept=tr.kept)
        for s in tr.spans:
            tid = STAGES.index(s.name) + 1 if s.name in STAGES else 99
            args = dict(common)
            args.update({k: v for k, v in s.attrs.items()})
            if s.name == "extract":
                args.update(bucket=dict(tr.bucket), halo=dict(tr.halo))
            if tr.error:
                args.update(error=tr.error, requeued=tr.requeued)
            events.append(dict(
                name=s.name, ph="X", pid=pid, tid=tid,
                ts=(s.t0 - base) * _US,
                dur=max(s.t1 - s.t0, 0.0) * _US,
                cat="serve", args=args))
    for w in warnings:
        events.append(dict(
            name=w.name, ph="i", s="g", pid=1, tid=98,
            ts=(w.t - base) * _US, cat="watchdog",
            args=dict(trace_id=w.trace_id, **w.attrs)))
    if warnings:
        pids.setdefault(1, _pid_name(1))

    meta: List[dict] = []
    for pid, name in sorted(pids.items()):
        meta.append(dict(name="process_name", ph="M", pid=pid, tid=0,
                         args=dict(name=name)))
        for i, stage in enumerate(STAGES):
            meta.append(dict(name="thread_name", ph="M", pid=pid, tid=i + 1,
                             args=dict(name=stage)))
        meta.append(dict(name="thread_name", ph="M", pid=pid, tid=98,
                         args=dict(name="watchdog")))
    return dict(traceEvents=meta + events, displayTimeUnit="ms")


def write_chrome_trace(source, path: str) -> dict:
    """``chrome_trace`` + dump to ``path``; returns the trace object."""
    obj = chrome_trace(source)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class _Registry:
    """Collects samples grouped by metric name so the rendered exposition
    carries ``# HELP``/``# TYPE`` headers once per series, immediately
    before that series' samples — the Prometheus text-format contract
    (samples of one metric must be contiguous, headers precede them)."""

    def __init__(self, default_labels: Optional[dict] = None):
        # name -> [help, type, [(labels, value), ...]] in first-seen order
        self._metrics: dict = {}
        # merged under every sample's labels — the model-family namespace
        # ("gnn" / "transformer" / "ssm") that keeps engines of different
        # families exported from one process off each other's series
        self.default_labels = dict(default_labels or {})

    def add(self, name: str, value, labels: Optional[dict] = None,
            help_: str = "", type_: str = "gauge") -> None:
        ent = self._metrics.get(name)
        if ent is None:
            ent = self._metrics[name] = [help_, type_, []]
        elif help_ and not ent[0]:
            ent[0] = help_
        ent[2].append((dict(self.default_labels, **(labels or {})),
                       float(value)))

    def render(self) -> str:
        out: List[str] = []
        for name, (help_, type_, samples) in self._metrics.items():
            out.append(f"# HELP {name} "
                       f"{help_ or name.replace('_', ' ')}")
            out.append(f"# TYPE {name} {type_}")
            for labels, value in samples:
                out.append(f"{name}{_fmt_labels(labels)} {value:g}")
        return "\n".join(out) + "\n"


def prometheus_text(snapshot: dict, tracer: Optional[SpanTracer] = None,
                    prefix: str = "serve") -> str:
    """Render an engine ``snapshot()`` dict (plus, optionally, the tracer's
    own counters) as Prometheus text exposition — a point-in-time scrape a
    textfile collector can ship as-is. Every series carries its
    ``# HELP``/``# TYPE`` headers; cost-model and SLO series appear when
    the snapshot includes them (engine constructed with an estimator /
    tracker). When the snapshot names its model family every series gets a
    ``family`` label, so scrapes from a GNN engine and a token engine in
    the same process never collide."""
    family = snapshot.get("family")
    reg = _Registry(dict(family=family) if family else None)
    m = snapshot

    reg.add(f"{prefix}_queries_total", m.get("queries", 0),
            help_="Queries served to completion", type_="counter")
    reg.add(f"{prefix}_batches_total", m.get("batches", 0),
            help_="Micro-batches served", type_="counter")
    reg.add(f"{prefix}_qps", m.get("qps", 0.0),
            help_="Served queries per second of elapsed serving time")
    reg.add(f"{prefix}_wall_seconds", m.get("serve_wall_s", 0.0),
            help_="Wall-clock seconds spent inside the serve loop")
    reg.add(f"{prefix}_overlap_ratio", m.get("overlap_ratio", 0.0),
            help_="Stage time hidden behind the other pipeline stage")
    reg.add(f"{prefix}_cache_hit_rate", m.get("cache_hit_rate", 0.0),
            help_="Fraction of queries answered from the full-graph cache")

    def _latency(stats: dict, labels: dict) -> None:
        for q in ("p50_ms", "p90_ms", "p99_ms", "mean_ms", "max_ms"):
            v = stats.get(q)
            if v is not None and v == v:        # skip NaN (empty window)
                reg.add(f"{prefix}_latency_ms", v,
                        dict(labels, quantile=q[:-3]),
                        help_="Latency summaries over the retained window")
        for k in ("count", "window"):
            if k in stats:
                reg.add(f"{prefix}_latency_{k}", stats[k], labels,
                        help_=f"Latency sample {k} behind the summaries")
    _latency(m.get("latency", {}), dict(group="query"))
    _latency(m.get("batch_latency", {}), dict(group="batch"))
    for stage, stats in sorted(m.get("batch_breakdown", {}).items()):
        if stage != "total":
            _latency(stats, dict(group=f"stage_{stage}"))

    tenant_help = dict(
        accepted="Submissions admitted", throttled="Submissions throttled",
        shed="Submissions shed at the queue-depth bound",
        queries="Queries answered",
        cost_throttled="Throttles charged to the cost-unit budget")
    for tenant, st in sorted(m.get("tenants", {}).items()):
        if not isinstance(st, dict):
            continue
        for k in ("accepted", "throttled", "shed", "queries",
                  "cost_throttled"):
            if k in st:
                reg.add(f"{prefix}_tenant_{k}_total", st[k],
                        dict(tenant=tenant), type_="counter",
                        help_=tenant_help.get(k, ""))
        if "cost_units" in st:
            reg.add(f"{prefix}_tenant_cost_units_total", st["cost_units"],
                    dict(tenant=tenant), type_="counter",
                    help_="Predicted cost units admitted for the tenant")
        if "attributed_cost_s" in st:
            reg.add(f"{prefix}_tenant_cost_attributed_seconds_total",
                    st["attributed_cost_s"], dict(tenant=tenant),
                    type_="counter",
                    help_="Measured batch service seconds attributed to "
                          "the tenant pro rata by predicted cost")
        _latency(st.get("latency", {}), dict(tenant=tenant))

    for k in ("pending", "pipeline_depth"):
        if k in snapshot:
            reg.add(f"{prefix}_{k}", snapshot[k])
    for k in ("compiles", "invalidations", "executor_compiles",
              "halo_bytes", "halo_tiles_shared", "halo_bytes_saved",
              "whale_splits"):
        if k in snapshot:
            reg.add(f"{prefix}_{k}_total", snapshot[k], type_="counter")
    for tag, b in sorted(snapshot.get("halo_bytes_by_tag", {}).items()):
        reg.add(f"{prefix}_halo_bytes_by_tag_total", b, dict(tag=tag),
                type_="counter")

    cost = snapshot.get("cost")
    if isinstance(cost, dict):
        reg.add(f"{prefix}_cost_queries_estimated_total",
                cost.get("queries_estimated", 0), type_="counter",
                help_="Submissions the cost model priced")
        reg.add(f"{prefix}_cost_batches_observed_total",
                cost.get("batches_observed", 0), type_="counter",
                help_="Served batches folded into cost calibration")
        if cost.get("typical_units") is not None:
            reg.add(f"{prefix}_cost_typical_units",
                    cost["typical_units"],
                    help_="EWMA predicted cost units per query")
        if cost.get("units_per_second") is not None:
            reg.add(f"{prefix}_cost_units_per_second",
                    cost["units_per_second"],
                    help_="Calibrated cost units per measured service "
                          "second (EWMA)")
        rho = cost.get("rank_correlation")
        if rho is not None and rho == rho:
            reg.add(f"{prefix}_cost_rank_correlation", rho,
                    help_="Spearman rho of predicted vs measured "
                          "per-batch cost")

    slo = snapshot.get("slo")
    if isinstance(slo, dict):
        for tenant, st in sorted(slo.get("tenants", {}).items()):
            reg.add(f"{prefix}_slo_burn_rate", st.get("burn_short", 0.0),
                    dict(tenant=tenant, window="short"),
                    help_="Error-budget burn rate over the sliding window")
            reg.add(f"{prefix}_slo_burn_rate", st.get("burn_long", 0.0),
                    dict(tenant=tenant, window="long"))
            reg.add(f"{prefix}_slo_budget_remaining",
                    st.get("budget_remaining", 1.0), dict(tenant=tenant),
                    help_="Error budget left at the long-window burn "
                          "(1 = untouched)")
            reg.add(f"{prefix}_slo_alerts_total", st.get("alerts", 0),
                    dict(tenant=tenant), type_="counter",
                    help_="Multi-window burn-rate alerts fired")
            reg.add(f"{prefix}_slo_depth_scale",
                    st.get("depth_scale", 1.0), dict(tenant=tenant),
                    help_="SLO autotune multiplier on the tenant's queue "
                          "depth")

    wd = snapshot.get("watchdogs", {})
    rc = wd.get("recompile", {})
    if rc:
        reg.add(f"{prefix}_steady_recompiles_total",
                rc.get("steady_recompiles", 0),
                help_="Steady-state XLA retraces flagged by the watchdog",
                type_="counter")
    tw = wd.get("transfer", {})
    for k in ("device_in_extract", "host_sync_in_launch"):
        if k in tw:
            reg.add(f"{prefix}_unexpected_transfers_total", tw[k],
                    dict(kind=k), type_="counter",
                    help_="Device/host syncs the transfer watchdog caught")

    if tracer is not None:
        ts = tracer.snapshot()
        for k in ("batches_seen", "batches_recorded", "outliers_recorded",
                  "errors_recorded", "warnings_recorded"):
            reg.add(f"{prefix}_trace_{k}_total", ts[k], type_="counter")
        reg.add(f"{prefix}_trace_retained", ts["retained"])
    return reg.render()
