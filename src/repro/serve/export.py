"""Offline exporters over the :class:`~repro.serve.trace.SpanTracer` ring
buffer: Chrome-trace JSON (``chrome://tracing`` / Perfetto loadable) and a
Prometheus text-exposition snapshot.

Both are pure functions of already-recorded data — nothing here runs in the
serving hot path. The Chrome trace lays out one PROCESS per shard (plus one
for unsharded batches) and one THREAD per pipeline stage, so Perfetto's
timeline shows queue-wait / extract / launch / compute as parallel tracks
and the PR 4 extract/compute overlap is visible as literal span overlap.
"""
from __future__ import annotations

import json
from typing import Iterable, List, Optional

from .trace import STAGES, BatchTrace, SpanTracer, WarningEvent

_US = 1e6  # chrome trace timestamps are microseconds


def _pid_of(tr: BatchTrace) -> int:
    # pid 1 = the unsharded engine; shard i gets pid 2+i
    return 1 if tr.shard is None else 2 + int(tr.shard)


def _pid_name(pid: int) -> str:
    return "serve" if pid == 1 else f"shard-{pid - 2}"


def chrome_trace(source) -> dict:
    """Build a Chrome-trace object (``json.dump`` it to a file and load in
    Perfetto) from a :class:`SpanTracer` or an iterable of trace records.

    Batch spans become "X" (complete) duration events on a (pid=shard,
    tid=stage) track; watchdog warnings become instant "i" events on a
    dedicated track. Timestamps are rebased to the earliest span so the
    viewer opens at t=0."""
    if isinstance(source, SpanTracer):
        records = source.records()
    else:
        records = list(source)
    batches = [r for r in records if isinstance(r, BatchTrace)]
    warnings = [r for r in records if isinstance(r, WarningEvent)]

    t0s = [s.t0 for tr in batches for s in tr.spans]
    t0s += [w.t for w in warnings]
    base = min(t0s) if t0s else 0.0

    events: List[dict] = []
    pids = {}
    for tr in batches:
        pid = _pid_of(tr)
        pids.setdefault(pid, _pid_name(pid))
        common = dict(trace_id=tr.trace_id, key=list(tr.key),
                      tenant=tr.tenant, n_queries=len(tr.queries),
                      kept=tr.kept)
        for s in tr.spans:
            tid = STAGES.index(s.name) + 1 if s.name in STAGES else 99
            args = dict(common)
            args.update({k: v for k, v in s.attrs.items()})
            if s.name == "extract":
                args.update(bucket=dict(tr.bucket), halo=dict(tr.halo))
            if tr.error:
                args.update(error=tr.error, requeued=tr.requeued)
            events.append(dict(
                name=s.name, ph="X", pid=pid, tid=tid,
                ts=(s.t0 - base) * _US,
                dur=max(s.t1 - s.t0, 0.0) * _US,
                cat="serve", args=args))
    for w in warnings:
        events.append(dict(
            name=w.name, ph="i", s="g", pid=1, tid=98,
            ts=(w.t - base) * _US, cat="watchdog",
            args=dict(trace_id=w.trace_id, **w.attrs)))
    if warnings:
        pids.setdefault(1, _pid_name(1))

    meta: List[dict] = []
    for pid, name in sorted(pids.items()):
        meta.append(dict(name="process_name", ph="M", pid=pid, tid=0,
                         args=dict(name=name)))
        for i, stage in enumerate(STAGES):
            meta.append(dict(name="thread_name", ph="M", pid=pid, tid=i + 1,
                             args=dict(name=stage)))
        meta.append(dict(name="thread_name", ph="M", pid=pid, tid=98,
                         args=dict(name="watchdog")))
    return dict(traceEvents=meta + events, displayTimeUnit="ms")


def write_chrome_trace(source, path: str) -> dict:
    """``chrome_trace`` + dump to ``path``; returns the trace object."""
    obj = chrome_trace(source)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _line(out: List[str], name: str, value, labels: Optional[dict] = None,
          help_: str = "", type_: str = "gauge") -> None:
    if help_:
        out.append(f"# HELP {name} {help_}")
        out.append(f"# TYPE {name} {type_}")
    out.append(f"{name}{_fmt_labels(labels or {})} {float(value):g}")


def prometheus_text(snapshot: dict, tracer: Optional[SpanTracer] = None,
                    prefix: str = "serve") -> str:
    """Render an engine ``snapshot()`` dict (plus, optionally, the tracer's
    own counters) as Prometheus text exposition — a point-in-time scrape a
    textfile collector can ship as-is."""
    out: List[str] = []
    m = snapshot

    _line(out, f"{prefix}_queries_total", m.get("queries", 0),
          help_="Queries served to completion", type_="counter")
    _line(out, f"{prefix}_batches_total", m.get("batches", 0),
          help_="Micro-batches served", type_="counter")
    _line(out, f"{prefix}_qps", m.get("qps", 0.0),
          help_="Served queries per second of elapsed serving time")
    _line(out, f"{prefix}_wall_seconds", m.get("serve_wall_s", 0.0),
          help_="Wall-clock seconds spent inside the serve loop")
    _line(out, f"{prefix}_overlap_ratio", m.get("overlap_ratio", 0.0),
          help_="Stage time hidden behind the other pipeline stage")
    _line(out, f"{prefix}_cache_hit_rate", m.get("cache_hit_rate", 0.0),
          help_="Fraction of queries answered from the full-graph cache")

    def _latency(stats: dict, labels: dict, first: bool) -> bool:
        for q in ("p50_ms", "p90_ms", "p99_ms", "mean_ms", "max_ms"):
            v = stats.get(q)
            if v is not None and v == v:        # skip NaN (empty window)
                _line(out, f"{prefix}_latency_ms", v,
                      dict(labels, quantile=q[:-3]),
                      help_=("Latency summaries over the retained window"
                             if first else ""))
                first = False
        for k in ("count", "window"):
            if k in stats:
                _line(out, f"{prefix}_latency_{k}", stats[k], labels)
        return first

    first = True
    first = _latency(m.get("latency", {}), dict(group="query"), first)
    first = _latency(m.get("batch_latency", {}), dict(group="batch"), first)
    for stage, stats in sorted(m.get("batch_breakdown", {}).items()):
        if stage != "total":
            first = _latency(stats, dict(group=f"stage_{stage}"), first)

    for tenant, st in sorted(m.get("tenants", {}).items()):
        if not isinstance(st, dict):
            continue
        for k in ("accepted", "throttled", "shed", "queries"):
            if k in st:
                _line(out, f"{prefix}_tenant_{k}_total", st[k],
                      dict(tenant=tenant), type_="counter")
        _latency(st.get("latency", {}), dict(tenant=tenant), False)

    for k in ("pending", "pipeline_depth"):
        if k in snapshot:
            _line(out, f"{prefix}_{k}", snapshot[k])
    for k in ("compiles", "invalidations", "executor_compiles",
              "halo_bytes", "halo_tiles_shared", "halo_bytes_saved"):
        if k in snapshot:
            _line(out, f"{prefix}_{k}_total", snapshot[k], type_="counter")
    for tag, b in sorted(snapshot.get("halo_bytes_by_tag", {}).items()):
        _line(out, f"{prefix}_halo_bytes_by_tag_total", b, dict(tag=tag),
              type_="counter")

    wd = snapshot.get("watchdogs", {})
    rc = wd.get("recompile", {})
    if rc:
        _line(out, f"{prefix}_steady_recompiles_total",
              rc.get("steady_recompiles", 0),
              help_="Steady-state XLA retraces flagged by the watchdog",
              type_="counter")
    tw = wd.get("transfer", {})
    for k in ("device_in_extract", "host_sync_in_launch"):
        if k in tw:
            _line(out, f"{prefix}_unexpected_transfers_total", tw[k],
                  dict(kind=k), type_="counter")

    if tracer is not None:
        ts = tracer.snapshot()
        for k in ("batches_seen", "batches_recorded", "outliers_recorded",
                  "errors_recorded", "warnings_recorded"):
            _line(out, f"{prefix}_trace_{k}_total", ts[k], type_="counter")
        _line(out, f"{prefix}_trace_retained", ts["retained"])
    return "\n".join(out) + "\n"
