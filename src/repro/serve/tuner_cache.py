"""Persistent BSpMM tuner cache.

``benchmarks/perf_hillclimb.py --bspmm`` sweeps the Pallas block-shape
space ``(rows, feats)``; on TPU a sweep is minutes of wall clock, so its
results persist here as JSON and survive restarts. Each MEASUREMENT is one
entry keyed by ``(graph stats fingerprint, block shape, backend, fused
flag)``; a lookup returns the fastest recorded block for a (fingerprint,
backend, fused) triple, which :class:`repro.serve.gnn_session.GraphStore`
uses to seed ``SessionPlan.bspmm_block`` when the store has no explicit
override.

File format (``schema`` guards future layout changes — unknown schemas are
ignored, not migrated)::

    {"schema": 1,
     "entries": {
       "<fp12>|cpu|fused=0|blk=8x128": {
         "stats": {"n_nodes": ..., "n_edges": ..., "n_feat": ...},
         "backend": "cpu", "fused": false,
         "block": [8, 128],        # null = kernel-native default
         "latency_s": 1.3e-4}}}

The fingerprint hashes the graph's aggregate STATS, not its topology: two
graphs with equal (n_nodes, n_edges, n_feat) share tuning, which is the
point — block-shape performance depends on scale, not on which specific
edges exist.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Optional, Tuple

import jax

SCHEMA = 1


def graph_stats(data) -> dict:
    """The aggregate stats a block-shape choice actually depends on."""
    return dict(n_nodes=int(data.n_nodes), n_edges=int(data.n_edges),
                n_feat=int(data.x.shape[1]))


def stats_fingerprint(stats: dict) -> str:
    canon = json.dumps(stats, sort_keys=True).encode()
    return hashlib.sha1(canon).hexdigest()[:12]


def _block_tag(block) -> str:
    return "default" if block is None else f"{block[0]}x{block[1]}"


def entry_key(stats: dict, block, backend: str, fused: bool) -> str:
    return (f"{stats_fingerprint(stats)}|{backend}|fused={int(fused)}"
            f"|blk={_block_tag(block)}")


class TunerCache:
    """JSON-file-backed measurement store, written through on record."""

    def __init__(self, path):
        self.path = Path(path)
        self.entries: dict = {}
        if self.path.exists():
            try:
                doc = json.loads(self.path.read_text())
            except (OSError, json.JSONDecodeError):
                doc = {}
            if doc.get("schema") == SCHEMA:
                self.entries = doc.get("entries", {})

    def _flush(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.path.write_text(json.dumps(
            {"schema": SCHEMA, "entries": self.entries},
            indent=1, sort_keys=True))

    def record(self, stats: dict, block, latency_s: float,
               fused: bool = False, backend: Optional[str] = None) -> str:
        """Store one measurement (overwrites a re-measured key) and flush."""
        backend = backend or jax.default_backend()
        key = entry_key(stats, block, backend, fused)
        self.entries[key] = dict(
            stats=stats, backend=backend, fused=bool(fused),
            block=None if block is None else list(block),
            latency_s=float(latency_s))
        self._flush()
        return key

    def lookup(self, stats: dict, fused: bool = False,
               backend: Optional[str] = None
               ) -> Optional[Tuple[int, int]]:
        """Fastest recorded block shape for this (stats, backend, fused)
        triple; None when nothing is recorded OR the kernel-native default
        is the fastest measurement (seeding then keeps block=None)."""
        backend = backend or jax.default_backend()
        fp = stats_fingerprint(stats)
        best, best_lat = None, None
        for e in self.entries.values():
            if (stats_fingerprint(e["stats"]) != fp
                    or e["backend"] != backend
                    or bool(e["fused"]) != bool(fused)):
                continue
            if best_lat is None or e["latency_s"] < best_lat:
                best_lat = e["latency_s"]
                best = e["block"]
        return None if best is None else tuple(best)
