"""Per-query span tracing + serving watchdogs — the event-level observability
layer under :mod:`repro.serve.metrics`.

:class:`~repro.serve.metrics.ServeMetrics` answers "how fast is the engine
overall"; this module answers "what happened to THIS batch": every served
micro-batch emits a :class:`BatchTrace` span tree (queue wait with the
fair-queueing virtual time at pick, extract, launch, device compute), tagged
with its bucket shape, tenant, owning shard and halo traffic, into a bounded
ring buffer. Recording is SAMPLED in steady state (1-in-``sample_every``)
but outliers beyond the rolling p99 batch time and every error/requeue path
are always kept — the traces one actually wants when a benchmark regresses.

Trace context lifecycle: a query carries context from ``submit()`` on — its
``qid``, ``t_submit`` and typed admission decision live on the
:class:`~repro.serve.gnn_engine.NodeQuery` itself; when the query is picked
into a batch the engine opens a :class:`BatchTrace` (the query's
``trace_id`` links to it), stage spans are appended as the batch moves
through the pipeline, and the trace is committed at finish (or on the
error/requeue path, always recorded). Exporters
(:mod:`repro.serve.export`) derive Chrome-trace JSON and Prometheus text
offline from the ring buffer — nothing in the hot path serializes.

Watchdogs turn two test-only invariants into runtime signals:

  * :class:`RecompileWatchdog` — the engines wire it into the jit-trace
    counters of every :class:`~repro.serve.session_core.ServeCore` and
    distributed-pass layer executor they touch. ``warmup()`` arms it; an
    armed watchdog seeing a trace means a STEADY-STATE recompile (a novel
    shape escaped the high-water buckets) and emits a structured warning
    event carrying the offending shape key.
  * :class:`TransferWatchdog` — the extract stage must be pure host work
    and the launch stage pure async dispatch. The watchdog checks both at
    the launch seam: a device-resident staged array means extraction
    touched the device; a launch returning concrete host arrays means the
    dispatch blocked on a device->host sync. (``strict_guard()``
    additionally arms jax's transfer guard around a block — it raises on
    real accelerators, and is a no-op on the CPU backend where device
    arrays are host-local.)
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

log = logging.getLogger(__name__)

# format version of the serialized trace records (and the chrome/prometheus
# exports derived from them)
TRACE_SCHEMA_VERSION = 1

# span names of the serving pipeline, in stage order — the per-stage tracks
# of the Chrome-trace export
STAGES = ("queue_wait", "extract", "launch", "compute")

# event names the replica tier emits through SpanTracer.event (always-kept
# WarningEvent records, like the watchdog firings): replica health
# transitions, failover requeues, reshard lifecycle phases, and the typed
# per-query failure paths of the bounded-retry / drain machinery
REPLICA_EVENTS = ("replica_unhealthy", "replica_recovered", "failover",
                  "reshard", "retry_exhausted", "drain")


@dataclasses.dataclass
class SpanEvent:
    """One timed stage of a batch's service: ``[t0, t1)`` wall-clock span
    (``time.perf_counter`` seconds) plus stage-specific attributes."""
    name: str
    t0: float
    t1: float
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def to_json(self) -> dict:
        return dict(name=self.name, t0=self.t0, t1=self.t1,
                    duration_s=self.duration_s, **self.attrs)


@dataclasses.dataclass
class BatchTrace:
    """Span tree of one micro-batch moving through the serving pipeline.

    ``vtime`` is the fair-queueing virtual start tag the scheduler used at
    pick (``overdue`` when the staleness bound preempted the virtual-time
    order); ``queries`` records each member query's qid/node/submit time and
    its queue wait at pick; ``bucket`` the padded launch shape; ``halo`` the
    sharded engine's per-batch halo traffic. ``kept`` says why the ring
    buffer retained this trace (``sampled`` / ``outlier`` / ``error``)."""
    trace_id: int
    key: tuple
    tenant: str
    shard: Optional[int]
    t_start: float                    # pick time (service start)
    t_end: float = 0.0
    spans: List[SpanEvent] = dataclasses.field(default_factory=list)
    queries: List[dict] = dataclasses.field(default_factory=list)
    bucket: Dict[str, object] = dataclasses.field(default_factory=dict)
    halo: Dict[str, object] = dataclasses.field(default_factory=dict)
    vtime: float = 0.0
    overdue: bool = False
    full_cache: bool = False
    error: str = ""
    requeued: bool = False
    kept: str = ""
    # cost-model view of the batch: summed predicted units, measured
    # service seconds, per-query predicted units, attribution — filled by
    # the engine's complete stage when a CostEstimator is wired in
    cost: Dict[str, object] = dataclasses.field(default_factory=dict)

    def span(self, name: str, t0: float, t1: float, **attrs) -> SpanEvent:
        ev = SpanEvent(name, t0, t1, attrs)
        self.spans.append(ev)
        return ev

    @property
    def total_s(self) -> float:
        return max(self.t_end - self.t_start, 0.0)

    def stage_s(self, name: str) -> float:
        """Summed duration of ``name`` spans (``compute`` prefers the
        double-count-free ``attributed_s`` the engine records, mirroring
        :meth:`ServeMetrics.record_stages`)."""
        total = 0.0
        for ev in self.spans:
            if ev.name == name:
                total += float(ev.attrs.get("attributed_s", ev.duration_s))
        return total

    def to_json(self) -> dict:
        return dict(type="batch", trace_id=self.trace_id,
                    key=list(self.key), tenant=self.tenant, shard=self.shard,
                    t_start=self.t_start, t_end=self.t_end,
                    total_s=self.total_s, vtime=self.vtime,
                    overdue=self.overdue, full_cache=self.full_cache,
                    n_queries=len(self.queries), queries=list(self.queries),
                    bucket=dict(self.bucket), halo=dict(self.halo),
                    cost=dict(self.cost),
                    error=self.error, requeued=self.requeued, kept=self.kept,
                    spans=[s.to_json() for s in self.spans])


@dataclasses.dataclass
class WarningEvent:
    """Structured out-of-band event (watchdog firings) — always recorded."""
    trace_id: int
    name: str
    t: float
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return dict(type="warning", trace_id=self.trace_id, name=self.name,
                    t=self.t, **self.attrs)


class SpanTracer:
    """Bounded ring buffer of batch traces + warning events, with steady-
    state sampling and always-on outlier/error capture.

    Retention policy per committed batch, in priority order: error/requeue
    paths are ALWAYS kept; batches whose total service time exceeds the
    rolling p99 (over the last ``outlier_window`` batches, once at least 32
    have been seen) are kept as outliers; otherwise 1-in-``sample_every``
    batches are kept. ``sample_every=1`` records everything (the acceptance
    and benchmark-export setting); ``enabled=False`` makes every call a
    no-op without the engines having to branch on None.

    Thread safety: the pipelined engines commit traces from worker threads
    while exporters snapshot the ring from the caller's thread, so ring and
    counter mutation is serialized under an internal lock — a
    :meth:`records` snapshot taken mid-append can never see a torn ring
    (a ``_pos`` read racing the wrap-around slice)."""

    OUTLIER_MIN_SAMPLES = 32

    def __init__(self, capacity: int = 4096, sample_every: int = 16,
                 outlier_pct: float = 99.0, outlier_window: int = 512,
                 enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if sample_every < 1:
            raise ValueError(f"sample_every must be >= 1, "
                             f"got {sample_every}")
        self.capacity = capacity
        self.sample_every = int(sample_every)
        self.outlier_pct = float(outlier_pct)
        self.enabled = enabled
        self._ring: List[object] = []
        self._pos = 0
        self._lock = threading.Lock()
        self._next_id = 0
        self.batches_seen = 0
        self.batches_recorded = 0
        self.outliers_recorded = 0
        self.errors_recorded = 0
        self.warnings_recorded = 0
        self._totals = np.zeros(int(outlier_window), np.float64)
        self._n_totals = 0

    # --------------------------------------------------------- recording ----
    def begin(self, key: tuple, tenant: str, shard: Optional[int],
              batch: list, t_pick: float, vtime: float = 0.0,
              overdue: bool = False) -> Optional[BatchTrace]:
        """Open the trace of one just-picked batch (``batch``: NodeQuery
        list). Cheap — retention is decided at :meth:`commit`."""
        if not self.enabled:
            return None
        with self._lock:
            trace_id = self._next_id
            self._next_id += 1
        tr = BatchTrace(trace_id=trace_id, key=key, tenant=tenant,
                        shard=shard, t_start=t_pick, vtime=vtime,
                        overdue=overdue)
        tr.queries = [dict(qid=q.qid, node=q.node, t_submit=q.t_submit,
                           queue_wait_s=t_pick - q.t_submit) for q in batch]
        for q in batch:          # link each query to its batch's trace
            q.trace_id = tr.trace_id
        tr.span("queue_wait",
                min((q.t_submit for q in batch), default=t_pick), t_pick,
                vtime=vtime, overdue=overdue)
        return tr

    def commit(self, trace: Optional[BatchTrace], error: str = "",
               requeued: bool = False) -> bool:
        """Close a batch trace and decide retention. Returns whether the
        ring buffer kept it."""
        if trace is None or not self.enabled:
            return False
        if error:
            trace.error = error
        trace.requeued = requeued
        if trace.t_end <= trace.t_start:
            trace.t_end = time.perf_counter()
        with self._lock:
            self.batches_seen += 1
            kept = ""
            if error or requeued:
                kept = "error"
                self.errors_recorded += 1
            elif self._is_outlier(trace.total_s):
                kept = "outlier"
                self.outliers_recorded += 1
            elif (self.batches_seen - 1) % self.sample_every == 0:
                kept = "sampled"
            self._push_total(trace.total_s)
            if kept:
                trace.kept = kept
                self._store(trace)
                self.batches_recorded += 1
        return bool(kept)

    def warning(self, name: str, **attrs) -> WarningEvent:
        """Record an always-kept structured warning event (watchdogs)."""
        with self._lock:
            ev = WarningEvent(trace_id=self._next_id, name=name,
                              t=time.perf_counter(), attrs=attrs)
            self._next_id += 1
            if self.enabled:
                self._store(ev)
                self.warnings_recorded += 1
        return ev

    def event(self, name: str, **attrs) -> WarningEvent:
        """Record an always-kept structured lifecycle event — the replica
        tier's channel for health transitions, failovers and reshard phases
        (see :data:`REPLICA_EVENTS`). Same record type and retention as
        :meth:`warning`; the separate name keeps call sites honest about
        whether they are reporting a problem or narrating a transition."""
        return self.warning(name, **attrs)

    def _push_total(self, total_s: float) -> None:
        self._totals[self._n_totals % self._totals.size] = total_s
        self._n_totals += 1

    def _is_outlier(self, total_s: float) -> bool:
        n = min(self._n_totals, self._totals.size)
        if n < self.OUTLIER_MIN_SAMPLES:
            return False
        return total_s > float(np.percentile(self._totals[:n],
                                             self.outlier_pct))

    def _store(self, record) -> None:
        if len(self._ring) < self.capacity:
            self._ring.append(record)
        else:
            self._ring[self._pos] = record
            self._pos = (self._pos + 1) % self.capacity

    # ------------------------------------------------------------ access ----
    def records(self) -> List[object]:
        """Retained records, oldest first (a consistent copy: the slice is
        taken under the ring lock, so concurrent commits from pipeline
        worker threads can never tear the wrap-around)."""
        with self._lock:
            return self._ring[self._pos:] + self._ring[:self._pos]

    def batch_traces(self) -> List[BatchTrace]:
        return [r for r in self.records() if isinstance(r, BatchTrace)]

    def warning_events(self) -> List[WarningEvent]:
        return [r for r in self.records() if isinstance(r, WarningEvent)]

    def clear(self) -> None:
        with self._lock:
            self._ring, self._pos = [], 0

    def snapshot(self) -> dict:
        return dict(schema_version=TRACE_SCHEMA_VERSION,
                    enabled=self.enabled, capacity=self.capacity,
                    sample_every=self.sample_every,
                    batches_seen=self.batches_seen,
                    batches_recorded=self.batches_recorded,
                    outliers_recorded=self.outliers_recorded,
                    errors_recorded=self.errors_recorded,
                    warnings_recorded=self.warnings_recorded,
                    retained=len(self._ring))


# ---------------------------------------------------------------------------
# Watchdogs
# ---------------------------------------------------------------------------

class RecompileWatchdog:
    """Turns the 'zero steady-state recompiles' test invariant into a
    runtime signal.

    The engines wire :meth:`on_recompile` into every serve core / layer
    executor they resolve (via the sessions' ``set_trace_hook``). While
    DISARMED (the warmup phase) jit traces are expected and ignored;
    ``warmup()`` arms the watchdog, after which every trace is a
    steady-state recompile: counted, logged, and emitted as a structured
    ``recompile`` warning event carrying the offending shape key."""

    def __init__(self, tracer: Optional[SpanTracer] = None,
                 family: str = "gnn"):
        self.tracer = tracer
        self.family = family
        self.armed = False
        self.steady_recompiles = 0
        self.last: Optional[dict] = None

    def arm(self) -> None:
        self.armed = True

    def disarm(self) -> None:
        self.armed = False

    def on_recompile(self, label: str, shape: Optional[dict]) -> None:
        """The session trace hook: ``label`` names the recompiled program
        (``core`` / ``shard<i>/core`` / ``executor/<layer>``), ``shape``
        the offending shape key (padded dims)."""
        if not self.armed:
            return
        self.steady_recompiles += 1
        self.last = dict(label=label, shape=shape)
        log.warning("steady-state recompile in %s: shape=%s", label, shape)
        if self.tracer is not None:
            self.tracer.warning("recompile", family=self.family,
                                label=label, shape=shape)

    def snapshot(self) -> dict:
        return dict(armed=self.armed, family=self.family,
                    steady_recompiles=self.steady_recompiles,
                    last=self.last)


class TransferWatchdog:
    """Counts unexpected device<->host syncs at the serving pipeline's
    stage boundaries.

    The contract the pipeline's overlap depends on: EXTRACT stages pure
    host arrays (a device-resident staged operand means extraction did
    device work — and will serialize against in-flight forwards), and
    LAUNCH is pure async dispatch (a launch returning concrete host arrays
    means something blocked on a device->host sync inside it). Both checks
    are O(#groups) isinstance probes per batch; violations are counted and
    (for the first ``max_events`` per kind) emitted as structured
    ``transfer`` warning events."""

    def __init__(self, tracer: Optional[SpanTracer] = None,
                 max_events: int = 16, family: str = "gnn"):
        self.tracer = tracer
        self.family = family
        self.max_events = max_events
        self.device_in_extract = 0     # staged arrays resident on device
        self.host_sync_in_launch = 0   # launch returned concrete host arrays

    def _emit(self, count: int, kind: str, **attrs) -> None:
        log.warning("unexpected transfer (%s): %s", kind, attrs)
        if self.tracer is not None and count <= self.max_events:
            self.tracer.warning("transfer", family=self.family,
                                kind=kind, **attrs)

    def check_prepared(self, prepared) -> None:
        """EXTRACT-purity check on a PreparedBatch about to launch."""
        for i, g in enumerate(getattr(prepared, "groups", ()) or ()):
            x = g.staged.x_pad
            if not isinstance(x, np.ndarray):
                self.device_in_extract += 1
                self._emit(self.device_in_extract, "device_in_extract",
                           group=i, array_type=type(x).__name__)

    def check_launched(self, devs) -> None:
        """LAUNCH-asynchrony check on the just-dispatched device handles."""
        for i, d in enumerate(devs or ()):
            if isinstance(d, np.ndarray):
                self.host_sync_in_launch += 1
                self._emit(self.host_sync_in_launch, "host_sync_in_launch",
                           group=i)

    @contextlib.contextmanager
    def strict_guard(self):
        """Arm jax's device->host transfer guard for the enclosed block:
        on real accelerators an unexpected sync RAISES (and is counted);
        on the CPU backend device arrays are host-local and the guard never
        fires — the isinstance checks above carry the signal there."""
        import jax
        try:
            with jax.transfer_guard_device_to_host("disallow"):
                yield
        except Exception:
            self.host_sync_in_launch += 1
            self._emit(self.host_sync_in_launch, "host_sync_in_launch",
                       source="transfer_guard")
            raise

    def snapshot(self) -> dict:
        return dict(family=self.family,
                    device_in_extract=self.device_in_extract,
                    host_sync_in_launch=self.host_sync_in_launch)
