"""Per-tenant SLOs: error-budget accounting, burn-rate alerts, and the
admission feedback loop.

An :class:`SLOPolicy` states a tenant's contract — target p99 latency and an
availability objective. The :class:`SLOTracker` watches the engine's
answered/rejected event stream and turns it into SRE-style error budgets:

  * every event is classified good/bad (a rejected submission, or an answer
    slower than the target p99, burns budget);
  * **burn rate** over a sliding window is the bad fraction divided by the
    budget fraction ``1 - availability`` — burn 1.0 consumes exactly the
    budget over the window, burn 10 consumes it 10x too fast;
  * alerts use the standard **multi-window** rule: a structured
    ``slo_burn`` :class:`~repro.serve.trace.WarningEvent` fires (into the
    engine's span tracer, so it lands in the Chrome-trace and Prometheus
    exports) only when BOTH the short and the long window burn above the
    threshold — the short window gates on what is happening NOW, the long
    window keeps a transient blip from paging;
  * **feedback**: when a tenant's long-window burn stays above the alert
    threshold, :meth:`check` shrinks the tenant's effective
    ``max_queue_depth`` on the :class:`~repro.serve.admission.
    AdmissionController` (multiplicative decrease, floored at
    ``min_depth_scale``) so overload is shed EARLIER, before it queues into
    latency; once the burn falls back under ``relax_burn`` the scale decays
    back toward 1.0.

The tracker is driven under the engine's ``_qlock`` (same discipline as the
admission controller) and takes explicit ``now`` timestamps, so tests and
benchmarks run it on an injected clock.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """One tenant's serving contract.

    ``target_p99_ms``   answered slower than this burns budget
                        (``inf`` = latency never burns);
    ``availability``    good-event objective in (0, 1) — the error budget
                        is ``1 - availability``;
    ``window_s``        long burn window (the budget accounting window);
    ``short_window_s``  fast burn window (default ``window_s / 10``);
    ``burn_alert``      multi-window alert threshold on the burn rate;
    ``relax_burn``      long-window burn below this relaxes the depth scale
                        back toward 1.0;
    ``autotune``        whether breaches shrink the tenant's effective
                        queue depth on the admission controller;
    ``min_depth_scale`` floor of the multiplicative depth shrink.
    """
    target_p99_ms: float = math.inf
    availability: float = 0.999
    window_s: float = 300.0
    short_window_s: Optional[float] = None
    burn_alert: float = 2.0
    relax_burn: float = 0.5
    autotune: bool = True
    min_depth_scale: float = 0.125

    def __post_init__(self):
        if not 0.0 < self.availability < 1.0:
            raise ValueError(f"availability must be in (0, 1), "
                             f"got {self.availability}")
        if not self.window_s > 0:
            raise ValueError(f"window_s must be > 0, got {self.window_s}")
        if self.short_window_s is not None \
                and not 0 < self.short_window_s <= self.window_s:
            raise ValueError(f"short_window_s must be in (0, window_s], "
                             f"got {self.short_window_s}")
        if not self.burn_alert > 0:
            raise ValueError(f"burn_alert must be > 0, "
                             f"got {self.burn_alert}")
        if not 0.0 < self.min_depth_scale <= 1.0:
            raise ValueError(f"min_depth_scale must be in (0, 1], "
                             f"got {self.min_depth_scale}")

    @property
    def budget(self) -> float:
        """The error-budget fraction: allowed bad events / events."""
        return 1.0 - self.availability

    @property
    def short_s(self) -> float:
        return self.short_window_s if self.short_window_s is not None \
            else self.window_s / 10.0


class _TenantBudget:
    """Sliding-window good/bad event stream of one tenant."""

    __slots__ = ("events", "alerts", "last_alert_t", "depth_scale",
                 "depth_shrinks", "depth_relaxes", "good", "bad")

    def __init__(self):
        self.events: Deque[Tuple[float, bool]] = deque()   # (t, bad)
        self.alerts = 0
        self.last_alert_t = -math.inf
        self.depth_scale = 1.0
        self.depth_shrinks = 0
        self.depth_relaxes = 0
        self.good = 0          # lifetime counters
        self.bad = 0


class SLOTracker:
    """Error budgets + burn-rate alerts + the admission feedback loop for
    the tenants that declared an :class:`SLOPolicy` (others are ignored —
    tenancy without an SLO costs nothing)."""

    def __init__(self, policies: Dict[str, SLOPolicy],
                 tracer=None, alert_cooldown_s: Optional[float] = None):
        self._policies = dict(policies or {})
        self.tracer = tracer
        # default cooldown: one alert per short window per tenant
        self.alert_cooldown_s = alert_cooldown_s
        self._tenants: Dict[str, _TenantBudget] = {}

    def policy(self, tenant: str) -> Optional[SLOPolicy]:
        return self._policies.get(tenant)

    def set_policy(self, tenant: str, policy: SLOPolicy) -> None:
        self._policies[tenant] = policy

    def _state(self, tenant: str) -> _TenantBudget:
        st = self._tenants.get(tenant)
        if st is None:
            st = self._tenants[tenant] = _TenantBudget()
        return st

    # ------------------------------------------------------------- intake ---
    def observe(self, tenant: str, now: float,
                latency_s: Optional[float] = None,
                rejected: bool = False) -> None:
        """Fold one event in: an answered query (``latency_s``) or a
        rejected submission (throttle/shed — an availability violation)."""
        pol = self._policies.get(tenant)
        if pol is None:
            return
        bad = bool(rejected)
        if not bad and latency_s is not None \
                and latency_s * 1e3 > pol.target_p99_ms:
            bad = True
        st = self._state(tenant)
        st.events.append((now, bad))
        if bad:
            st.bad += 1
        else:
            st.good += 1
        self._prune(st, pol, now)

    def _prune(self, st: _TenantBudget, pol: SLOPolicy, now: float) -> None:
        horizon = now - pol.window_s
        ev = st.events
        while ev and ev[0][0] < horizon:
            ev.popleft()

    def burn_rate(self, tenant: str, window_s: float,
                  now: float) -> float:
        """Bad fraction over the trailing window divided by the budget
        fraction (0.0 with no events in the window)."""
        pol = self._policies.get(tenant)
        st = self._tenants.get(tenant)
        if pol is None or st is None:
            return 0.0
        horizon = now - window_s
        total = bad = 0
        for t, b in reversed(st.events):
            if t < horizon:
                break
            total += 1
            bad += b
        if total == 0:
            return 0.0
        return (bad / total) / max(pol.budget, 1e-9)

    # ----------------------------------------------------- alerts/feedback ---
    def check(self, now: float, admission=None) -> list:
        """Evaluate every tracked tenant: fire ``slo_burn`` warnings at
        multi-window burn breaches (cooldown-limited) and, when
        ``admission`` is given, auto-tune the tenant's effective queue
        depth. Returns the alert dicts fired this call."""
        fired = []
        for tenant, st in self._tenants.items():
            pol = self._policies.get(tenant)
            if pol is None or not st.events:
                continue
            self._prune(st, pol, now)
            burn_long = self.burn_rate(tenant, pol.window_s, now)
            burn_short = self.burn_rate(tenant, pol.short_s, now)
            breach = (burn_long >= pol.burn_alert
                      and burn_short >= pol.burn_alert)
            cooldown = self.alert_cooldown_s if self.alert_cooldown_s \
                is not None else pol.short_s
            if breach and now - st.last_alert_t >= cooldown:
                st.alerts += 1
                st.last_alert_t = now
                alert = dict(tenant=tenant, burn_short=burn_short,
                             burn_long=burn_long,
                             threshold=pol.burn_alert,
                             window_s=pol.window_s,
                             short_window_s=pol.short_s,
                             budget_remaining=self._remaining(burn_long))
                fired.append(alert)
                if self.tracer is not None:
                    self.tracer.warning("slo_burn", **alert)
            if pol.autotune and admission is not None:
                self._autotune(tenant, st, pol, burn_long, admission)
        return fired

    def _autotune(self, tenant: str, st: _TenantBudget, pol: SLOPolicy,
                  burn_long: float, admission) -> None:
        """p99-vs-SLO feedback: sustained burn shrinks the tenant's
        effective queue depth (shed earlier, before overload queues into
        latency); a healthy burn decays the scale back toward 1.0."""
        scale = st.depth_scale
        if burn_long >= pol.burn_alert:
            scale = max(pol.min_depth_scale, scale * 0.5)
            if scale != st.depth_scale:
                st.depth_shrinks += 1
        elif burn_long <= pol.relax_burn and scale < 1.0:
            scale = min(1.0, scale * 1.5)
            st.depth_relaxes += 1
        if scale != st.depth_scale:
            st.depth_scale = scale
            admission.set_depth_scale(tenant, scale)

    @staticmethod
    def _remaining(burn_long: float) -> float:
        """Window budget left at the current long burn (1.0 = untouched,
        0.0 = exhausted)."""
        return max(0.0, 1.0 - burn_long)

    # -------------------------------------------------------------- state ---
    def snapshot(self, now: float) -> dict:
        tenants = {}
        for tenant in sorted(self._policies):
            pol = self._policies[tenant]
            st = self._tenants.get(tenant)
            burn_long = self.burn_rate(tenant, pol.window_s, now)
            burn_short = self.burn_rate(tenant, pol.short_s, now)
            tenants[tenant] = dict(
                target_p99_ms=(None if math.isinf(pol.target_p99_ms)
                               else pol.target_p99_ms),
                availability=pol.availability,
                window_s=pol.window_s,
                good=(st.good if st else 0),
                bad=(st.bad if st else 0),
                burn_short=burn_short,
                burn_long=burn_long,
                budget_remaining=self._remaining(burn_long),
                alerts=(st.alerts if st else 0),
                depth_scale=(st.depth_scale if st else 1.0),
                depth_shrinks=(st.depth_shrinks if st else 0),
                depth_relaxes=(st.depth_relaxes if st else 0),
            )
        return dict(tenants=tenants)
