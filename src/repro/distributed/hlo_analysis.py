"""Collective-byte accounting from compiled HLO text (DESIGN.md §7).

``cost_analysis()`` has no collective numbers, so we parse the (per-device,
SPMD-partitioned) HLO: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction's result shape gives the payload.
Wire-byte conventions (ring algorithms, per device):
    all-gather         output_bytes          (each device receives V_out-V_in)
    all-reduce         2 x operand_bytes     (reduce-scatter + all-gather)
    reduce-scatter     operand_bytes
    all-to-all         operand_bytes
    collective-permute operand_bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s/#*]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int]
    count_by_op: Dict[str, int]

    @property
    def wire_bytes(self) -> int:
        """Per-device wire bytes with the ring conventions above."""
        total = 0
        for op, b in self.bytes_by_op.items():
            total += 2 * b if op == "all-reduce" else b
        return total

    @property
    def raw_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def analyze_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by_op: Dict[str, int] = defaultdict(int)
    count_by_op: Dict[str, int] = defaultdict(int)
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        # async pairs appear as -start/-done; count each logical op once
        line = m.group(0)
        if "-done(" in line:
            continue
        bytes_by_op[op] += _shape_bytes(type_str)
        count_by_op[op] += 1
    return CollectiveStats(dict(bytes_by_op), dict(count_by_op))


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
