"""Logical-axis sharding rules -> NamedShardings (DESIGN.md §6).

Megatron-style tensor parallelism on the "model" axis (column-parallel into
attention/FFN, row-parallel out, vocab-sharded embedding), optional FSDP on
the "data" axis for weights (training shapes: optimizer state must fit),
batch over ("pod","data").

Rules are keyed on the LAST path component of each parameter — the single
source of truth shared by train, serve, and the dry-run.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _key_of(path) -> str:
    last = path[-1]
    return last.key if hasattr(last, "key") else str(getattr(last, "idx", last))


def _parent_key(path) -> str:
    for entry in reversed(path[:-1]):
        if hasattr(entry, "key"):
            return entry.key
    return ""


# fp rule table: key -> (spec builder). d=fsdp axis or None, m="model".
def _fp_spec(key: str, parent: str, ndim: int, d, m) -> P:
    col = {  # column-parallel: (in, out_model)
        "wq", "wk", "wv", "wi", "wz", "wx", "wdt", "wr", "wg",
        "shared_wi", "cm_wk",
    }
    row = {  # row-parallel: (in_model, out)
        "wo", "shared_wo", "cm_wv",
    }
    model_vec = {"A_log", "dt_bias", "D", "w0", "u", "ln_scale", "norm_scale"}
    if key == "table":
        return P(m, d)                       # vocab-sharded embedding
    if ndim == 3 and key == "wi":            # MoE experts: EP over model —
        return P(m, d, None)                 # MUST precede the 2-D col rule
    if ndim == 3 and key == "wo":
        return P(m, None, d)
    if key in col:
        return P(d, m) if ndim == 2 else P(None)
    if key in row:
        return P(m, d) if ndim == 2 else P(None)
    if key == "cm_wr":
        return P(d, None)
    if key in ("wB", "wC"):                  # mamba B/C proj: small state dim
        return P(d, None)
    if key == "conv_w":
        return P(None, m)
    if key in model_vec:
        return P(m) if ndim == 1 else P(None, m)
    if key == "router":
        return P(None, None)
    if parent == "moe" or key in ("wi", "wo") and ndim == 3:
        pass
    if ndim == 3 and key == "wi":
        return P(m, d, None)                 # experts over model (EP)
    if ndim == 3 and key == "wo":
        return P(m, None, d)
    if key in ("w1", "w2") and parent == "projector":
        return P(None, None)
    if key == "frontend_proj":
        return P(None, None)
    if key == "wA":
        return P(d, None)
    if key == "wB" and ndim == 2:
        return P(None, m)
    return P(*([None] * min(ndim, 0) or []))  # replicate


QUANT_REPLICATE = False  # §Perf C2: replicate (tiny) packed weights


def param_pspec(path, leaf, fsdp: bool) -> P:
    key = _key_of(path)
    parent = _parent_key(path)
    d = "data" if fsdp else None
    ndim = getattr(leaf, "ndim", 0)
    if key in ("packed", "scale") and QUANT_REPLICATE:
        return P(*([None] * ndim))
    if key in ("packed", "scale"):
        # bit-packed projections: packed is (out, in/32) = TRANSPOSE of the
        # fp weight, so swap the fp rule's two axes.
        fp_key = parent
        base = _fp_spec(fp_key, _parent_key(path[:-1]), 2, d, "model")
        a, b = (list(base) + [None, None])[:2]
        if key == "scale":
            return P(b)
        return P(b, a)
    spec = _fp_spec(key, parent, ndim, d, "model")
    # pad the spec rank to the leaf rank
    entries = list(spec)
    if len(entries) < ndim:
        entries += [None] * (ndim - len(entries))
    return P(*entries[:ndim]) if ndim else P()


def param_shardings(abstract_params: Any, mesh: Mesh,
                    fsdp: bool = False) -> Any:
    def one(path, leaf):
        return NamedSharding(mesh, param_pspec(path, leaf, fsdp))
    return jax.tree_util.tree_map_with_path(one, abstract_params)


def batch_pspec(mesh: Mesh) -> P:
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    return P(dp)


def _dp_size(mesh: Mesh) -> int:
    size = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        size *= mesh.shape["pod"]
    return size


def _dp0(mesh: Mesh):
    dp = batch_pspec(mesh)
    return dp[0] if len(dp) == 1 else tuple(dp)


def data_shardings(abstract_batch: Any, mesh: Mesh) -> Any:
    dp0, dsz = _dp0(mesh), _dp_size(mesh)

    def one(leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd and leaf.shape[0] % dsz == 0:
            return NamedSharding(mesh, P(dp0, *([None] * (nd - 1))))
        return NamedSharding(mesh, P(*([None] * nd)))
    return jax.tree.map(one, abstract_batch)


def cache_shardings(abstract_cache: Any, mesh: Mesh) -> Any:
    """Decode caches: batch over dp, heads over model.

    k/v (B,S,H,hd) -> P(dp,None,"model",None); SSM states (B,H,...) ->
    P(dp,"model",...); tails (B,d) -> P(dp,None); enc memory (B,T,d) ->
    P(dp,None,None). When B doesn't divide dp (long_500k, B=1) the KV-cache
    SEQUENCE axis takes the dp shards instead (sequence-parallel decode) and
    per-batch states replicate across dp."""
    dp0, dsz = _dp0(mesh), _dp_size(mesh)

    def one(path, leaf):
        nd = leaf.ndim
        key = _key_of(path)
        b_ok = nd >= 1 and leaf.shape[0] % dsz == 0
        bax = dp0 if b_ok else None
        if key in ("k", "v", "k_scale", "v_scale") and nd == 4:
            seq_ax = None if b_ok else (
                dp0 if leaf.shape[1] % dsz == 0 else None)
            spec = P(bax, seq_ax, "model", None)
        elif key == "S" and nd >= 3:
            spec = P(bax, "model", *([None] * (nd - 2)))
        elif key == "conv" and nd == 3:
            spec = P(bax, None, "model")
        elif key == "enc_memory" and nd == 3:
            spec = P(bax, None, None)
        elif nd >= 1:
            spec = P(bax, *([None] * (nd - 1)))
        else:
            spec = P()
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, abstract_cache)


def logits_sharding(mesh: Mesh, batch: int = 0) -> NamedSharding:
    dp0, dsz = _dp0(mesh), _dp_size(mesh)
    if batch and batch % dsz != 0:
        return NamedSharding(mesh, P(None, None, "model"))
    return NamedSharding(mesh, P(dp0, None, "model"))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
