"""Architecture registry: the 10 assigned configs + the paper's own GNNs."""
from __future__ import annotations

import dataclasses

from .base import ModelConfig, ShapeConfig, SHAPES

# --- assigned architectures (exact figures from the task sheet) -------------

llava_next_34b = ModelConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000, head_dim=128,
    act="swiglu", frontend_dim=1024, frontend_len=2880)

minitron_8b = ModelConfig(
    name="minitron-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=16384, vocab=256000, head_dim=128,
    act="swiglu")

starcoder2_3b = ModelConfig(
    name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
    n_heads=24, n_kv_heads=2, d_ff=12288, vocab=49152, head_dim=128,
    act="gelu")

stablelm_1_6b = ModelConfig(
    name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=5632, vocab=100352, head_dim=64,
    act="swiglu")

smollm_135m = ModelConfig(
    name="smollm-135m", family="dense", n_layers=30, d_model=576,
    n_heads=9, n_kv_heads=3, d_ff=1536, vocab=49152, head_dim=64,
    act="swiglu", tie_embeddings=True)

zamba2_1_2b = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32000, head_dim=64,
    act="swiglu", ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_every=6)

qwen2_moe_a2_7b = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=151936, head_dim=128,
    act="swiglu", moe_experts=60, moe_top_k=4, moe_shared_ff=5632,
    moe_every=1)

llama4_scout_17b_a16e = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048, head_dim=128,
    act="swiglu", moe_experts=16, moe_top_k=1, moe_shared_ff=8192,
    moe_every=1)

seamless_m4t_medium = ModelConfig(
    name="seamless-m4t-medium", family="audio", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206, head_dim=64,
    act="gelu", enc_layers=12, dec_layers=12, frontend_dim=1024,
    frontend_len=1600)

rwkv6_3b = ModelConfig(
    name="rwkv6-3b", family="ssm", n_layers=32, d_model=2560,
    n_heads=0, n_kv_heads=0, d_ff=8960, vocab=65536, head_dim=64,
    ssm_state=64, ssm_head_dim=64)


ARCHS = {c.name: c for c in [
    llava_next_34b, minitron_8b, starcoder2_3b, stablelm_1_6b, smollm_135m,
    zamba2_1_2b, qwen2_moe_a2_7b, llama4_scout_17b_a16e, seamless_m4t_medium,
    rwkv6_3b]}

# shapes each arch actually runs (long_500k: sub-quadratic decode only)
LONG_CONTEXT_ARCHS = ("zamba2-1.2b", "rwkv6-3b")


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def shapes_for(name: str):
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if name in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2 if not cfg.attn_every else 4),
        d_model=128, d_ff=256, vocab=512, head_dim=32,
        frontend_dim=64 if cfg.frontend_dim else 0,
        frontend_len=8 if cfg.frontend_len else 0,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2))
    if cfg.moe_experts:
        # capacity_factor large enough that the tiny expert count never drops
        # tokens: capacity drops are batch-composition-dependent, so they
        # break prefill-by-decode vs. parallel-forward parity at smoke scale
        # (16 tokens over 4 experts bind at the default 1.25).
        kw.update(moe_experts=4, moe_top_k=min(cfg.moe_top_k, 2),
                  moe_shared_ff=64 if cfg.moe_shared_ff else 0,
                  capacity_factor=8.0)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=32)
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.enc_layers:
        kw.update(enc_layers=2, dec_layers=2)
    return dataclasses.replace(cfg, **kw)


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config",
           "shapes_for", "reduced_config", "LONG_CONTEXT_ARCHS"]
