"""Config system: model / shape / run configs + TP-divisibility resolution.

``ModelConfig`` captures every assigned architecture with one dataclass; the
block pattern (dense attention / MoE / Mamba2 / RWKV6 / enc-dec) is selected
per-layer by ``block_pattern()``. ``resolve_for_mesh()`` applies the padding
policy of DESIGN.md §5 (q-heads -> multiple of TP, kv-heads -> divisor of TP
then replicate, vocab -> multiple of TP*128, experts -> multiple of TP) and
records the padding so the roofline can report useful-FLOP ratios.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


# The four assigned input-shape sets (LM transformer shapes).
SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int              # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    norm: str = "rmsnorm"
    act: str = "swiglu"       # swiglu | gelu
    rope_theta: float = 1e6
    tie_embeddings: bool = False

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_ff: int = 0        # fused shared-expert FFN width
    moe_every: int = 1            # MoE block every k layers (else dense)
    capacity_factor: float = 1.25

    # SSM (Mamba2 for hybrid, RWKV6 for ssm family)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0           # hybrid: shared attn block every k layers
    moe_groups: int = 0           # >1: per-dp-shard grouped dispatch (§Perf)
    kv_cache_quant: str = "none"  # none | int8 (§Perf: decode memory term)

    # enc-dec (audio family)
    enc_layers: int = 0
    dec_layers: int = 0
    frontend_dim: int = 0         # stub modality frontend feature width
    frontend_len: int = 0         # stub frontend sequence (frames / patches)

    # quantization (the paper's technique as an LM feature)
    quant: str = "none"           # none | bitgnn (bit-packed binary linears)

    # numerics
    dtype: str = "bfloat16"

    # --- resolved-for-mesh fields (filled by resolve_for_mesh) -------------
    tp: int = 1
    n_heads_padded: int = 0
    n_kv_heads_padded: int = 0
    kv_replication: int = 1
    vocab_padded: int = 0
    moe_experts_padded: int = 0
    ssm_heads_padded: int = 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def block_pattern(self) -> Sequence[str]:
        """Per-layer block kinds for the decoder stack."""
        if self.family == "ssm":
            return ("rwkv",) * self.n_layers
        if self.family == "hybrid":
            # Zamba2: Mamba2 backbone + ONE weight-tied shared attention
            # block invoked every `attn_every` layers.
            out = []
            for i in range(self.n_layers):
                out.append("mamba_attn" if self.attn_every and
                           (i + 1) % self.attn_every == 0 else "mamba")
            return tuple(out)
        if self.family == "moe":
            return tuple("moe" if (i + 1) % self.moe_every == 0 else "dense"
                         for i in range(self.n_layers))
        return ("dense",) * self.n_layers

    def resolve_for_mesh(self, tp: int) -> "ModelConfig":
        """Apply the TP padding policy; returns a new resolved config."""
        hp = _ceil_mult(self.n_heads, tp) if self.n_heads else 0
        if self.n_kv_heads:
            kvp = _pad_to_divisor_or_multiple(self.n_kv_heads, tp)
            kv_rep = max(1, tp // kvp) if kvp < tp else 1
        else:
            kvp, kv_rep = 0, 1
        vp = _ceil_mult(self.vocab, tp * 128)
        ep = _ceil_mult(self.moe_experts, tp) if self.moe_experts else 0
        sp = _ceil_mult(self.ssm_heads, tp) if self.ssm_state else 0
        return dataclasses.replace(
            self, tp=tp, n_heads_padded=hp, n_kv_heads_padded=kvp,
            kv_replication=kv_rep, vocab_padded=vp, moe_experts_padded=ep,
            ssm_heads_padded=sp)

    # ---------------- analytic parameter/FLOP accounting --------------------

    def param_count(self, padded: bool = False) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d = self.d_model
        v = self.vocab_padded if (padded and self.vocab_padded) else self.vocab
        h = (self.n_heads_padded if (padded and self.n_heads_padded)
             else self.n_heads)
        kv = (self.n_kv_heads_padded if (padded and self.n_kv_heads_padded)
              else self.n_kv_heads)
        e = (self.moe_experts_padded if (padded and self.moe_experts_padded)
             else self.moe_experts)
        total = v * d                              # embedding
        if not self.tie_embeddings:
            total += v * d                         # lm head
        ff_mult = 3 if self.act == "swiglu" else 2

        def attn_params():
            return d * (h + 2 * kv) * self.head_dim + h * self.head_dim * d

        def mlp_params(ff):
            return ff_mult * d * ff

        for kind in self.block_pattern():
            if kind == "dense":
                total += attn_params() + mlp_params(self.d_ff)
            elif kind == "moe":
                total += attn_params() + e * mlp_params(self.d_ff)
                total += d * e                     # router
                if self.moe_shared_ff:
                    total += mlp_params(self.moe_shared_ff)
            elif kind in ("mamba", "mamba_attn"):
                di, ns = self.d_inner, self.ssm_state
                nh = self.ssm_heads
                total += d * (2 * di + 2 * ns + nh) + di * d + 4 * (di + 2 * ns)
                if kind == "mamba_attn":
                    pass  # shared (weight-tied) attn counted once below
            elif kind == "rwkv":
                total += 4 * d * d                 # r,k,v,out time-mix
                total += d * (self.d_ff) + self.d_ff * d + d * d  # channel mix
                total += 6 * d + 2 * (d * 32 + 32 * d)  # decay lora etc.
        if self.family == "hybrid" and self.attn_every:
            total += attn_params() + mlp_params(self.d_ff)  # ONE shared block
        if self.is_encdec:
            # encoder blocks + cross attention in decoder
            total += self.enc_layers * (attn_params() + mlp_params(self.d_ff))
            total += self.dec_layers * attn_params()        # cross attn
            total += self.frontend_dim * d                  # frontend proj
        if self.family == "vlm":
            total += self.frontend_dim * d + d * d          # projector MLP
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.moe_experts:
            return self.param_count()
        d = self.d_model
        ff_mult = 3 if self.act == "swiglu" else 2
        inactive = ((self.moe_experts - self.moe_top_k)
                    * ff_mult * d * self.d_ff
                    * sum(1 for k in self.block_pattern() if k == "moe"))
        return int(self.param_count() - inactive)


def _ceil_mult(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_to_divisor_or_multiple(kv: int, tp: int) -> int:
    """Smallest k >= kv with tp % k == 0 or k % tp == 0."""
    k = kv
    while not (tp % k == 0 or k % tp == 0):
        k += 1
    return k
