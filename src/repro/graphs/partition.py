"""1-D block-row graph partitioning for distributed BSpMM.

Distribution scheme (DESIGN.md §6): tile-rows are split into contiguous
shards over the ``data`` mesh axis; every shard holds its FRDC slice locally
and all-gathers the (bit-packed!) activation matrix per layer. Packing makes
the gathered payload 32x smaller than fp — the paper's memory saving becomes
a collective saving at scale.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core import frdc
from repro.core.frdc import FRDCMatrix, TILE


@dataclasses.dataclass
class RowShard:
    adj: FRDCMatrix          # local block-rows, col space = FULL graph
    row_start: int           # first (node) row owned
    row_end: int             # one past last node row owned


def shard_tile_bounds(rows: np.ndarray, n: int, n_shards: int) -> np.ndarray:
    """Contiguous tile-row shard boundaries, balanced by EDGE count.

    Returns ``(n_shards + 1,)`` tile-row indices (first 0, last
    ``ceil(n/TILE)``); shard ``s`` owns tile-rows ``[b[s], b[s+1])``.
    Balancing by edges (not nodes) mitigates power-law row skew — the same
    reasoning as the paper's warp-balance concern (§3.3.1), applied at the
    inter-chip level. Deterministic: a pure function of the row histogram.
    """
    rows = np.asarray(rows, np.int64)
    n_tr = -(-n // TILE)
    counts = np.bincount(rows // TILE, minlength=n_tr)
    cum = np.concatenate([[0], np.cumsum(counts)])
    total = cum[-1]
    bounds = np.zeros(n_shards + 1, np.int64)
    for s in range(n_shards):
        target = total * (s + 1) / n_shards
        tr_end = int(np.searchsorted(cum, target)) if s < n_shards - 1 else n_tr
        bounds[s + 1] = max(tr_end, bounds[s])  # allow empty on tiny graphs
    return bounds


def shard_node_bounds(rows: np.ndarray, n: int, n_shards: int) -> np.ndarray:
    """``shard_tile_bounds`` in NODE units: tile-aligned except the last,
    which is clamped to ``n``. The routing table of the sharded serving
    subsystem is exactly this array (node -> owning shard by bisection)."""
    return np.minimum(shard_tile_bounds(rows, n, n_shards) * TILE, n)


def partition_rows(rows: np.ndarray, cols: np.ndarray, n: int,
                   n_shards: int, kind: str = "gcn") -> List[RowShard]:
    """Split an edge list into ``n_shards`` contiguous tile-row shards
    (boundaries from :func:`shard_tile_bounds`); every shard holds its FRDC
    block-rows over the FULL column space.
    """
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    order = np.argsort(rows, kind="stable")
    rows_s, cols_s = rows[order], cols[order]
    # cumulative edges per tile-row boundary
    n_tr = -(-n // TILE)
    edge_tile_row = rows_s // TILE
    counts = np.bincount(edge_tile_row, minlength=n_tr)
    cum = np.concatenate([[0], np.cumsum(counts)])
    bounds = shard_tile_bounds(rows, n, n_shards)
    shards = []
    prev_tr = 0
    for s in range(n_shards):
        tr_end = int(bounds[s + 1])
        lo, hi = cum[prev_tr], cum[tr_end]
        r_lo, r_hi = prev_tr * TILE, min(tr_end * TILE, n)
        sel = slice(lo, hi)
        local_rows = rows_s[sel] - r_lo
        local_cols = cols_s[sel]
        scales = {}
        if kind == "gcn":
            # global degrees for exact normalization
            deg = np.bincount(rows, minlength=n) + 1.0
            dinv = 1.0 / np.sqrt(deg)
            loop = np.arange(r_lo, r_hi, dtype=np.int64)
            local_rows = np.concatenate([local_rows, loop - r_lo])
            local_cols = np.concatenate([local_cols, loop])
            scales = dict(row_scale=dinv[r_lo:r_hi], col_scale=dinv)
        elif kind == "mean":
            deg = np.bincount(rows, minlength=n)
            scales = dict(row_scale=1.0 / np.maximum(deg[r_lo:r_hi], 1))
        adj = frdc.from_coo(local_rows, local_cols, max(r_hi - r_lo, TILE), n,
                            **scales)
        shards.append(RowShard(adj=adj, row_start=r_lo, row_end=r_hi))
        prev_tr = tr_end
    return shards


def shard_stats(shards: List[RowShard]) -> dict:
    edges = np.array([s.adj.nnz for s in shards], np.float64)
    return dict(
        n_shards=len(shards),
        edges_mean=float(edges.mean()),
        edges_max=float(edges.max()),
        imbalance=float(edges.max() / max(edges.mean(), 1.0)),
    )
