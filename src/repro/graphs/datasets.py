"""Synthetic stat-matched graph datasets (no network access in this box).

Each generator matches the node/edge/feature/class counts of the paper's
Table 2 and produces a *learnable* node-classification task: a planted
partition with homophilous edges, power-law degrees, and class-correlated
sparse binary features (Cora/CiteSeer-style bags of words). Accuracy-parity
experiments (fp32 vs binarized) are therefore meaningful even though the
graphs are synthetic; the latency/memory benchmarks depend only on the
matched size/sparsity statistics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core import frdc


@dataclasses.dataclass
class GraphData:
    name: str
    x: np.ndarray            # (N, F) float32 features
    y: np.ndarray            # (N,) int32 labels
    edges: np.ndarray        # (2, E) int64 directed edge list
    n_classes: int
    train_mask: np.ndarray
    val_mask: np.ndarray
    test_mask: np.ndarray

    @property
    def n_nodes(self) -> int:
        return self.x.shape[0]

    @property
    def n_edges(self) -> int:
        return self.edges.shape[1]

    def adjacency(self, kind: str = "gcn") -> frdc.FRDCMatrix:
        r, c = self.edges
        if kind == "gcn":
            return frdc.gcn_normalized(r, c, self.n_nodes)
        if kind == "mean":
            return frdc.mean_normalized(r, c, self.n_nodes)
        if kind == "binary":
            return frdc.from_coo(r, c, self.n_nodes, self.n_nodes)
        raise ValueError(kind)


# Table 2 of the paper.
DATASET_STATS: Dict[str, dict] = {
    "cora":     dict(n_nodes=2708,   n_edges=13264,      n_feat=1433, n_classes=7),
    "pubmed":   dict(n_nodes=19717,  n_edges=108356,     n_feat=500,  n_classes=3),
    "citeseer": dict(n_nodes=3327,   n_edges=12431,      n_feat=3703, n_classes=6),
    "flickr":   dict(n_nodes=89250,  n_edges=899756,     n_feat=500,  n_classes=7),
    "reddit":   dict(n_nodes=232965, n_edges=114615892,  n_feat=602,  n_classes=41),
}


def make_dataset(name: str, seed: int = 0, scale: float = 1.0,
                 homophily: float = 0.85, feature_signal: float = 0.08,
                 ) -> GraphData:
    """Generate a stat-matched synthetic dataset.

    ``scale`` < 1 shrinks node/edge counts proportionally (used to fit the
    Reddit-class graph in CPU benchmark time; ``--full`` passes 1.0).
    """
    stats = DATASET_STATS[name]
    n = max(int(stats["n_nodes"] * scale), 64)
    e = max(int(stats["n_edges"] * scale), 4 * n)
    f = stats["n_feat"]
    c = stats["n_classes"]
    rng = np.random.default_rng(seed)

    y = rng.integers(0, c, size=n).astype(np.int32)

    # power-law degree propensities (alpha ~ 2.1, truncated)
    prop = rng.pareto(1.1, size=n) + 1.0
    prop /= prop.sum()

    half = e // 2
    src = rng.choice(n, size=half, p=prop)
    same = rng.random(half) < homophily
    dst = np.empty(half, np.int64)
    # homophilous endpoints: random node of the same class
    order = np.argsort(y, kind="stable")
    class_starts = np.searchsorted(y[order], np.arange(c))
    class_ends = np.searchsorted(y[order], np.arange(c), side="right")
    class_ends = np.append(class_starts[1:], n)
    cls = y[src]
    lo, hi = class_starts[cls], class_ends[cls]
    pick = (lo + (rng.random(half) * np.maximum(hi - lo, 1)).astype(np.int64))
    dst[same] = order[pick[same]]
    dst[~same] = rng.choice(n, size=(~same).sum())
    keep = src != dst
    src, dst = src[keep], dst[keep]
    edges = np.concatenate([np.stack([src, dst]), np.stack([dst, src])], axis=1)
    edges = np.unique(edges, axis=1)

    # class-correlated sparse binary features (bag-of-words style)
    words_per_class = max(f // c, 1)
    x = (rng.random((n, f)) < 0.015).astype(np.float32)
    for k in range(c):
        cols = slice(k * words_per_class, min((k + 1) * words_per_class, f))
        rows = np.nonzero(y == k)[0]
        boost = rng.random((rows.size, cols.stop - cols.start)) < feature_signal
        x[rows, cols] = np.maximum(x[rows, cols], boost.astype(np.float32))

    # transductive split: 20 train/class, 500 val, rest test (Planetoid-style)
    train_mask = np.zeros(n, bool)
    for k in range(c):
        idx = np.nonzero(y == k)[0]
        train_mask[rng.choice(idx, size=min(20, idx.size), replace=False)] = True
    rest = np.nonzero(~train_mask)[0]
    rng.shuffle(rest)
    val_mask = np.zeros(n, bool)
    val_mask[rest[:min(500, rest.size // 4)]] = True
    test_mask = ~(train_mask | val_mask)

    return GraphData(name=name, x=x, y=y, edges=edges.astype(np.int64),
                     n_classes=c, train_mask=train_mask, val_mask=val_mask,
                     test_mask=test_mask)
