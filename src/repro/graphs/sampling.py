"""Inductive-learning samplers: GraphSAGE neighbor sampling and GraphSAINT
node-budget subgraph sampling (paper §2.1 / §4.1 inductive GNNs)."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.core import frdc
from .datasets import GraphData


def _build_csr(edges: np.ndarray, n: int):
    order = np.argsort(edges[0], kind="stable")
    dst_sorted = edges[1][order]
    counts = np.bincount(edges[0], minlength=n)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, dst_sorted


def sage_sample(data: GraphData, batch_nodes: np.ndarray, fanouts=(10, 10),
                seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """GraphSAGE fixed-fanout neighbor expansion.

    Returns (subgraph node ids, (2, E_sub) edge list reindexed into the
    subgraph). Layers expand from the batch outward with the given fanouts.
    """
    rng = np.random.default_rng(seed)
    indptr, indices = _build_csr(data.edges, data.n_nodes)
    frontier = np.unique(batch_nodes)
    nodes = [frontier]
    for fan in fanouts:
        nxt = []
        for u in frontier:
            nbrs = indices[indptr[u]:indptr[u + 1]]
            if nbrs.size > fan:
                nbrs = rng.choice(nbrs, size=fan, replace=False)
            nxt.append(nbrs)
        frontier = np.unique(np.concatenate(nxt)) if nxt else np.array([], np.int64)
        nodes.append(frontier)
    sub_nodes = np.unique(np.concatenate(nodes))
    remap = -np.ones(data.n_nodes, np.int64)
    remap[sub_nodes] = np.arange(sub_nodes.size)
    src, dst = data.edges
    keep = (remap[src] >= 0) & (remap[dst] >= 0)
    sub_edges = np.stack([remap[src[keep]], remap[dst[keep]]])
    return sub_nodes, sub_edges


def saint_node_sampler(data: GraphData, budget: int,
                       seed: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """GraphSAINT node sampler: degree-proportional node budget subgraphs."""
    rng = np.random.default_rng(seed)
    deg = np.bincount(data.edges[0], minlength=data.n_nodes) + 1.0
    p = deg / deg.sum()
    remapped = -np.ones(data.n_nodes, np.int64)
    while True:
        sub_nodes = np.unique(rng.choice(data.n_nodes, size=budget, p=p))
        remapped[:] = -1
        remapped[sub_nodes] = np.arange(sub_nodes.size)
        src, dst = data.edges
        keep = (remapped[src] >= 0) & (remapped[dst] >= 0)
        yield sub_nodes, np.stack([remapped[src[keep]], remapped[dst[keep]]])


def subgraph_adjacency(sub_nodes: np.ndarray, sub_edges: np.ndarray,
                       kind: str = "gcn") -> frdc.FRDCMatrix:
    n = sub_nodes.size
    r, c = sub_edges
    if kind == "gcn":
        return frdc.gcn_normalized(r, c, n)
    if kind == "mean":
        return frdc.mean_normalized(r, c, n)
    return frdc.from_coo(r, c, n, n)
