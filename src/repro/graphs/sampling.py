"""Inductive-learning samplers (GraphSAGE neighbor sampling, GraphSAINT
node-budget subgraphs; paper §2.1 / §4.1) plus the deterministic k-hop
subgraph API the serving engine uses to answer node-level queries."""
from __future__ import annotations

from typing import Iterator, NamedTuple, Tuple

import numpy as np

from repro.core import frdc
from .datasets import GraphData


class CSRGraph(NamedTuple):
    """Host-side CSR over the directed edge list: row -> neighbor columns.

    Rows are the RECEIVING side of aggregation (``out[r] += x[c]`` for every
    edge (r, c)), matching ``frdc.from_coo(edges[0], edges[1], ...)``.
    """
    indptr: np.ndarray     # (N+1,) int64
    indices: np.ndarray    # (E,) int64
    n_nodes: int

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]


def to_csr(edges: np.ndarray, n_nodes: int) -> CSRGraph:
    indptr, indices = _build_csr(np.asarray(edges, np.int64), n_nodes)
    return CSRGraph(indptr=indptr, indices=indices, n_nodes=n_nodes)


def _build_csr(edges: np.ndarray, n: int):
    order = np.argsort(edges[0], kind="stable")
    dst_sorted = edges[1][order]
    counts = np.bincount(edges[0], minlength=n)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, dst_sorted


def _gather_neighbors(csr: CSRGraph, nodes: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenated neighbor lists of ``nodes`` + per-node counts, fully
    vectorized (this sits on the per-batch serving hot path — no Python
    loop over nodes)."""
    counts = csr.indptr[nodes + 1] - csr.indptr[nodes]
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64), counts
    ends = np.cumsum(counts)
    offs = np.arange(total) - np.repeat(ends - counts, counts)
    idx = np.repeat(csr.indptr[nodes], counts) + offs
    return csr.indices[idx], counts


# public alias: the sharded k-hop router expands per-shard frontiers with
# the exact same vectorized gather the single-host path uses, so cross-shard
# extraction reproduces the single-host neighbor ordering bit-for-bit.
gather_neighbors = _gather_neighbors


def khop_nodes(csr: CSRGraph, seeds: np.ndarray, k: int) -> np.ndarray:
    """Sorted node ids of the FULL (unsampled) k-hop closure of ``seeds``.

    Every node at distance <= k-1 from a seed has its complete neighborhood
    inside the closure, so an L-layer GNN restricted to the k=L closure
    reproduces full-graph outputs for the seeds exactly.
    """
    seen = np.zeros(csr.n_nodes, bool)
    frontier = np.unique(np.asarray(seeds, np.int64))
    seen[frontier] = True
    for _ in range(k):
        if frontier.size == 0:
            break
        nbrs, _ = _gather_neighbors(csr, frontier)
        if nbrs.size == 0:
            break
        nbrs = np.unique(nbrs)
        frontier = nbrs[~seen[nbrs]]
        seen[frontier] = True
    return np.nonzero(seen)[0]


def induced_edges(csr: CSRGraph, sub_nodes: np.ndarray) -> np.ndarray:
    """(2, E_sub) edge list among ``sub_nodes``, reindexed into the subgraph
    (relative node order preserved — sub id i is the i-th smallest full id)."""
    remap = -np.ones(csr.n_nodes, np.int64)
    remap[sub_nodes] = np.arange(sub_nodes.size)
    cols, counts = _gather_neighbors(csr, sub_nodes)
    if cols.size == 0:
        return np.zeros((2, 0), np.int64)
    rows = np.repeat(sub_nodes, counts)
    keep = remap[cols] >= 0
    return np.stack([remap[rows[keep]], remap[cols[keep]]])


def khop_subgraph(csr: CSRGraph, seeds: np.ndarray, k: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deterministic full k-hop subgraph extraction for serving.

    Returns (sub_nodes sorted, (2, E_sub) reindexed edges, positions of the
    seeds inside ``sub_nodes`` in the order given).
    """
    seeds = np.asarray(seeds, np.int64)
    sub_nodes = khop_nodes(csr, seeds, k)
    sub_edges = induced_edges(csr, sub_nodes)
    seed_pos = np.searchsorted(sub_nodes, seeds)
    return sub_nodes, sub_edges, seed_pos


class ExtractedSubgraph(NamedTuple):
    """One extracted k-hop serving subgraph — the unit of work the serve
    pipeline's EXTRACT stage hands to the compute stage. Pure host arrays:
    producing one involves no device work, so extraction can run on a
    background worker while the previous batch's jitted forward is in
    flight."""
    sub_nodes: np.ndarray   # (n_sub,) sorted global node ids
    sub_edges: np.ndarray   # (2, E_sub) edges reindexed into the subgraph
    seed_pos: np.ndarray    # positions of the seeds inside sub_nodes


def extract_khop(csr: CSRGraph, seeds: np.ndarray,
                 k: int) -> ExtractedSubgraph:
    """Extraction entry point of the serving pipeline: ``khop_subgraph``
    bundled into the prepared-batch object the sessions stage from."""
    return ExtractedSubgraph(*khop_subgraph(csr, seeds, k))


def sage_sample(data: GraphData, batch_nodes: np.ndarray, fanouts=(10, 10),
                seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """GraphSAGE fixed-fanout neighbor expansion.

    Returns (subgraph node ids, (2, E_sub) edge list reindexed into the
    subgraph). Layers expand from the batch outward with the given fanouts.
    """
    rng = np.random.default_rng(seed)
    indptr, indices = _build_csr(data.edges, data.n_nodes)
    frontier = np.unique(batch_nodes)
    nodes = [frontier]
    for fan in fanouts:
        nxt = []
        for u in frontier:
            nbrs = indices[indptr[u]:indptr[u + 1]]
            if nbrs.size > fan:
                nbrs = rng.choice(nbrs, size=fan, replace=False)
            nxt.append(nbrs)
        frontier = np.unique(np.concatenate(nxt)) if nxt else np.array([], np.int64)
        nodes.append(frontier)
    sub_nodes = np.unique(np.concatenate(nodes))
    remap = -np.ones(data.n_nodes, np.int64)
    remap[sub_nodes] = np.arange(sub_nodes.size)
    src, dst = data.edges
    keep = (remap[src] >= 0) & (remap[dst] >= 0)
    sub_edges = np.stack([remap[src[keep]], remap[dst[keep]]])
    return sub_nodes, sub_edges


def saint_node_sampler(data: GraphData, budget: int,
                       seed: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """GraphSAINT node sampler: degree-proportional node budget subgraphs."""
    rng = np.random.default_rng(seed)
    deg = np.bincount(data.edges[0], minlength=data.n_nodes) + 1.0
    p = deg / deg.sum()
    remapped = -np.ones(data.n_nodes, np.int64)
    while True:
        sub_nodes = np.unique(rng.choice(data.n_nodes, size=budget, p=p))
        remapped[:] = -1
        remapped[sub_nodes] = np.arange(sub_nodes.size)
        src, dst = data.edges
        keep = (remapped[src] >= 0) & (remapped[dst] >= 0)
        yield sub_nodes, np.stack([remapped[src[keep]], remapped[dst[keep]]])


def subgraph_adjacency(sub_nodes: np.ndarray, sub_edges: np.ndarray,
                       kind: str = "gcn") -> frdc.FRDCMatrix:
    n = sub_nodes.size
    r, c = sub_edges
    if kind == "gcn":
        return frdc.gcn_normalized(r, c, n)
    if kind == "mean":
        return frdc.mean_normalized(r, c, n)
    return frdc.from_coo(r, c, n, n)
