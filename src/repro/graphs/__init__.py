"""Graph substrate: synthetic datasets, samplers, distributed partitioning."""
from . import datasets, partition, sampling
