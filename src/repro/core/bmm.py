"""BMM — dense binary matmul variants (paper §3.1.2, low-level group 1).

Seven variants, named ``BMM.<X><W><O>`` where X = input-activation precision,
W = weight precision, O = output precision; F = full (fp32/bf16), B = binary.

    FBF, FBB, BBF, BBB, BFF, BFB, FFB

Weight storage for the ``?B?`` variants: ``BinTensor`` of ``W.T`` — packed
along the contraction axis K with a per-output-column positive scale
(Bi-GCN's L1 factorization). Binary activations are ``BinTensor`` packed along
their feature axis (== K) with per-row scale.

Auxiliary BIN/SCL are FUSED into these functions (the paper keeps them inside
BMM "to avoid invocation overhead"); the SCL-before-BIN elision of §3.1.2 is
applied automatically whenever the output is binary.

These are the reference (pure-jnp) semantics; ``repro.kernels.ops`` routes the
hot variants to Pallas kernels on TPU and falls back here on CPU.
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp

from . import bitops
from .binarize import BinTensor, binarize_matrix, dequantize

BMM_VARIANTS = ("FBF", "FBB", "BBF", "BBB", "BFF", "BFB", "FFB")


def quantize_weight(w: jax.Array) -> BinTensor:
    """Offline weight binarization: BinTensor of W.T with col scales."""
    return binarize_matrix(w.T, scale="row")


def quantize_act(x: jax.Array) -> BinTensor:
    """Activation binarization with per-row L1 scale (Bi-GCN)."""
    return binarize_matrix(x, scale="row")


def _xnor_matmul(xa: BinTensor, wt: BinTensor) -> jax.Array:
    """sign(X) @ sign(W) via XNOR-popc on packed words -> (M, N) int32."""
    assert xa.n == wt.n, (xa.n, wt.n)
    return bitops.bmm_xnor_words(xa.packed, wt.packed, xa.n)


def bmm(x: Union[jax.Array, BinTensor], wt: Union[jax.Array, BinTensor],
        variant: str, out_scale: bool = True):
    """Dispatch a BMM variant.

    ``x``: (M, K) fp array for ``F??`` or BinTensor (packed along K) for ``B??``.
    ``wt``: BinTensor of W.T for ``?B?`` or (K, N) fp array for ``?F?``.
    Returns (M, N) fp for ``??F`` or BinTensor for ``??B``.
    ``out_scale``: compute the output BinTensor's row scale (skipped when the
    caller knows the consumer elides it — e.g. feeding BSpMM.BBB).
    """
    if variant not in BMM_VARIANTS:
        raise ValueError(f"unknown BMM variant {variant!r}")
    xa, wp, op = variant

    if xa == "F":
        assert isinstance(x, jax.Array) or not isinstance(x, BinTensor)
        if wp == "B":
            w_eff = dequantize(wt).T        # (K, N) = ±1 * col-scale
            full = x @ w_eff
        else:  # FFB
            full = x @ wt
    else:  # binary activation
        assert isinstance(x, BinTensor)
        if wp == "B":
            acc = _xnor_matmul(x, wt).astype(jnp.float32)
            if op == "B":
                # row scale (x.scale) and col scale (wt.scale) are positive:
                # both elided under the output BIN (§3.1.2 insight).
                full = acc
            else:
                full = acc * x.scale * wt.scale.reshape(1, -1)
        else:  # BF?: ±1 activation times fp weight
            xp = bitops.unpack_pm1(x.packed, x.n)      # reference unpack
            full = (xp @ wt)
            if op == "F":
                full = full * x.scale

    if op == "F":
        return full
    scale = jnp.mean(jnp.abs(full), axis=-1, keepdims=True) if out_scale \
        else jnp.ones((full.shape[0], 1), full.dtype)
    return BinTensor(packed=bitops.sign_bits(full, axis=-1), scale=scale,
                     n=full.shape[-1])


def bmm_reference_fp(x: jax.Array, w: jax.Array, variant: str) -> jax.Array:
    """Full-precision oracle of what each variant APPROXIMATES.

    Used by accuracy tests: binarizes operands per the variant letters with
    sign+L1 scaling, then does exact fp math. The packed `bmm` above must
    agree with this to fp tolerance.
    """
    xa, wp, op = variant
    if xa == "B":
        xs = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)
        x = jnp.where(x >= 0, 1.0, -1.0) * xs
    if wp == "B":
        ws = jnp.mean(jnp.abs(w), axis=0, keepdims=True)
        w = jnp.where(w >= 0, 1.0, -1.0) * ws
    out = x @ w
    del op  # output binarization handled by the caller
    return out
