"""BitGNN core: the paper's contribution as composable JAX modules."""
from . import abstraction, binarize, bitops, bmm, bspmm, frdc, tuner
from .abstraction import MMSpMM, MMAdd, check_chain, op, precision_of
from .binarize import BinTensor, binarize_matrix, dequantize, straight_through_sign
from .bmm import bmm as bmm_apply, quantize_act, quantize_weight
from .bspmm import bspmm as bspmm_apply
from .frdc import FRDCMatrix, from_coo, from_dense, gcn_normalized, mean_normalized

__all__ = [
    "abstraction", "binarize", "bitops", "bmm", "bspmm", "frdc", "tuner",
    "MMSpMM", "MMAdd", "check_chain", "op", "precision_of",
    "BinTensor", "binarize_matrix", "dequantize", "straight_through_sign",
    "bmm_apply", "quantize_act", "quantize_weight", "bspmm_apply",
    "FRDCMatrix", "from_coo", "from_dense", "gcn_normalized", "mean_normalized",
]
