"""Tuning utilities (paper §3.4).

Auto-tunes (a) the precision-variant assignment of the high-level blocks in a
binary GNN and (b) the trinary-dot-product reconciliation mode (§3.2.2), by
timing candidate configurations on the actual graph. Type-correctness of
candidates is guaranteed by ``abstraction.check_chain``; accuracy deltas are
measured against a reference forward so the user can pick a point on the
accuracy/speed curve.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from .abstraction import MMSPMM_PAIRINGS, MMSpMM, check_chain
from .bspmm import TRINARY_DEFAULT


@dataclasses.dataclass
class Candidate:
    layer_variants: Sequence[tuple[str, str]]   # (mm, spmm) per layer
    trinary_mode: str = TRINARY_DEFAULT

    def name(self) -> str:
        layers = ";".join(f"{m}+{s}" for m, s in self.layer_variants)
        return f"[{layers}|{self.trinary_mode}]"


@dataclasses.dataclass
class TuneResult:
    candidate: Candidate
    latency_s: float
    output_delta: float


def legal_two_layer_candidates(first_in: str = "F",
                               last_out: str = "F") -> Sequence[Candidate]:
    """Enumerate type-correct 2-layer GCN variant assignments (§3.1.2)."""
    out = []
    for (m1, s1), (m2, s2) in itertools.product(MMSPMM_PAIRINGS, repeat=2):
        if m1.split(".")[1][0] != first_in:
            continue
        if s2.split(".")[1][-1] != last_out:
            continue
        # inter-layer precision: spmm1 out == mm2 in
        if s1.split(".")[1][-1] != m2.split(".")[1][0]:
            continue
        for mode in ("s2_and_andnot", "s3_two_popc"):
            out.append(Candidate(((m1, s1), (m2, s2)), mode))
    return tuple(out)


def _time_call(fn: Callable, *args, repeats: int = 3) -> float:
    fn(*args)  # compile / warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        best = min(best, time.perf_counter() - t0)
    return best


def tune(build_forward: Callable[[Candidate], Callable],
         args: tuple,
         candidates: Sequence[Candidate],
         reference: Optional[jax.Array] = None,
         repeats: int = 3) -> Sequence[TuneResult]:
    """Time every candidate forward; rank by latency.

    ``build_forward(candidate)`` returns a jittable callable; ``reference``
    (optional) is a fp32 forward output for accuracy-delta reporting.
    """
    results = []
    for cand in candidates:
        fwd = jax.jit(build_forward(cand))
        latency = _time_call(fwd, *args, repeats=repeats)
        delta = float("nan")
        if reference is not None:
            out = fwd(*args)
            out = out if isinstance(out, jax.Array) else out[0]
            delta = float(jnp.mean(jnp.abs(out - reference)))
        results.append(TuneResult(cand, latency, delta))
    return sorted(results, key=lambda r: r.latency_s)


def best(results: Sequence[TuneResult],
         max_delta: Optional[float] = None) -> TuneResult:
    ok = [r for r in results
          if max_delta is None or r.output_delta <= max_delta]
    if not ok:
        raise ValueError("no candidate satisfies the accuracy bound")
    return ok[0]
