"""FRDC — Fine-Representing Dynamic-Coarsening bit-sparse format (paper §3.2.1).

Storage (fine, host-built with numpy):
    * the adjacency is cut into 4x4 bit-tiles; only non-empty tiles are kept;
    * tiles of one tile-row (4 matrix rows) are grouped into TILE-GROUPS of 8
      (zero-padded), so one group covers 32 gathered columns = one machine word;
    * arrays — ``tiles`` (G, 8) uint16, ``col_idx`` (G, 8) int32,
      ``group_row`` (G,) int32, ``group_first`` (G,) int32, plus
      ``row_ptr``/``grp_ptr`` CSR pointers in tile/group units.

Compute (coarse, on device): :func:`coarsen_groups` stitches a group's eight
4x4 tiles into four 32-bit words (one per matrix row in the tile-row) — the
TPU analogue of the paper's ``__shfl_sync`` bit-concatenation (Step ③).

Weighted graphs: a normalized adjacency ``D^-1/2 (A+I) D^-1/2`` (GCN) or
``D^-1 A`` (mean aggregation) factorizes EXACTLY as ``diag(r) @ A_bin @
diag(c)`` with ``A_bin`` binary — FRDC stores the optional positive ``row_scale``
/ ``col_scale`` vectors next to the bits (paper §3.1.2 "factorization vector").
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import bitops

TILE = 4                # fine tile side (paper's 4x4 choice)
GROUP = 8               # tiles per group: 8 * 4 = 32 columns = one word
GROUP_COLS = TILE * GROUP  # 32


class FRDCMatrix(NamedTuple):
    """Device-resident FRDC sparse bit-matrix."""
    tiles: jax.Array        # (G, GROUP) uint16 — 4x4 bit-tiles, LSB = (r0,c0)
    col_idx: jax.Array      # (G, GROUP) int32 — tile-column index (pad: 0)
    group_row: jax.Array    # (G,) int32 — tile-row of each group
    group_first: jax.Array  # (G,) int32 — 1 iff first group of its tile-row
    grp_ptr: jax.Array      # (R+1,) int32 — group extents per tile-row
    n_rows: int
    n_cols: int
    nnz: int                # true number of edges (pre-padding)
    row_scale: Optional[jax.Array] = None  # (n_rows,) positive or None
    col_scale: Optional[jax.Array] = None  # (n_cols,) positive or None

    @property
    def n_tile_rows(self) -> int:
        return -(-self.n_rows // TILE)

    @property
    def n_groups(self) -> int:
        return int(self.tiles.shape[0])

    def nbytes(self) -> int:
        """Device bytes of the bit representation (paper's Peak-Mem metric)."""
        total = self.tiles.size * 2 + self.col_idx.size * 4
        total += self.group_row.size * 4 + self.group_first.size * 4
        total += self.grp_ptr.size * 4
        for s in (self.row_scale, self.col_scale):
            if s is not None:
                total += s.size * s.dtype.itemsize
        return int(total)


def from_coo(rows: np.ndarray, cols: np.ndarray, n_rows: int, n_cols: int,
             row_scale: Optional[np.ndarray] = None,
             col_scale: Optional[np.ndarray] = None,
             device: bool = True) -> FRDCMatrix:
    """Build FRDC from an edge list (host-side, numpy).

    ``device=False`` keeps the arrays numpy-backed — the serving EXTRACT
    stage builds per-batch subgraph matrices with it so extraction stays
    pure host work (no device puts, no eager-op XLA compiles for every
    fresh subgraph shape); the jit call boundary converts them on launch.
    """
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    if rows.size:
        assert rows.max() < n_rows and cols.max() < n_cols
    n_tr = -(-n_rows // TILE)
    n_tc = -(-n_cols // TILE)

    tile_r, in_r = np.divmod(rows, TILE)
    tile_c, in_c = np.divmod(cols, TILE)
    tile_id = tile_r * n_tc + tile_c
    uniq, inv = np.unique(tile_id, return_inverse=True)
    bits = np.zeros(uniq.shape[0], np.uint16)
    np.bitwise_or.at(bits, inv, (np.uint16(1) << (in_r * TILE + in_c).astype(np.uint16)))
    utile_r = (uniq // n_tc).astype(np.int64)
    utile_c = (uniq % n_tc).astype(np.int64)
    # np.unique sorts tile_id == (tile_r, tile_c) lexicographically: CSR order.
    row_counts = np.bincount(utile_r, minlength=n_tr)
    grp_counts = -(-row_counts // GROUP)
    grp_counts = np.maximum(grp_counts, 0)
    G = int(grp_counts.sum())
    G = max(G, 1)  # keep shapes non-empty for degenerate graphs

    tiles = np.zeros((G, GROUP), np.uint16)
    col_idx = np.zeros((G, GROUP), np.int32)
    group_row = np.zeros((G,), np.int32)
    group_first = np.zeros((G,), np.int32)
    grp_ptr = np.zeros(n_tr + 1, np.int32)

    row_ptr = np.zeros(n_tr + 1, np.int64)
    np.cumsum(row_counts, out=row_ptr[1:])
    g = 0
    for r in range(n_tr):
        grp_ptr[r] = g
        lo, hi = row_ptr[r], row_ptr[r + 1]
        nt = hi - lo
        if nt == 0:
            continue
        ng = -(-nt // GROUP)
        row_tiles = np.zeros(ng * GROUP, np.uint16)
        row_cols = np.zeros(ng * GROUP, np.int32)
        row_tiles[:nt] = bits[lo:hi]
        row_cols[:nt] = utile_c[lo:hi]
        tiles[g:g + ng] = row_tiles.reshape(ng, GROUP)
        col_idx[g:g + ng] = row_cols.reshape(ng, GROUP)
        group_row[g:g + ng] = r
        group_first[g] = 1
        g += ng
    grp_ptr[n_tr] = g
    if g == 0:  # degenerate: single zero group mapped to row 0
        group_first[0] = 1

    xp = jnp if device else np
    return FRDCMatrix(
        tiles=xp.asarray(tiles), col_idx=xp.asarray(col_idx),
        group_row=xp.asarray(group_row), group_first=xp.asarray(group_first),
        grp_ptr=xp.asarray(grp_ptr), n_rows=int(n_rows), n_cols=int(n_cols),
        nnz=int(rows.size),
        row_scale=(None if row_scale is None
                   else xp.asarray(row_scale, xp.float32)),
        col_scale=(None if col_scale is None
                   else xp.asarray(col_scale, xp.float32)),
    )


def from_dense(a: np.ndarray, **kw) -> FRDCMatrix:
    r, c = np.nonzero(np.asarray(a) != 0)
    return from_coo(r, c, a.shape[0], a.shape[1], **kw)


def gcn_normalized(rows: np.ndarray, cols: np.ndarray, n: int,
                   add_self_loops: bool = True) -> FRDCMatrix:
    """FRDC of ``D^-1/2 (A+I) D^-1/2`` — exact binary factorization (GCN)."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    if add_self_loops:
        loop = np.arange(n, dtype=np.int64)
        rows = np.concatenate([rows, loop])
        cols = np.concatenate([cols, loop])
    deg = np.bincount(rows, minlength=n).astype(np.float64)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    return from_coo(rows, cols, n, n, row_scale=dinv, col_scale=dinv)


def mean_normalized(rows: np.ndarray, cols: np.ndarray, n: int) -> FRDCMatrix:
    """FRDC of ``D^-1 A`` — mean aggregator (SAGEConv)."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    deg = np.bincount(rows, minlength=n).astype(np.float64)
    dinv = 1.0 / np.maximum(deg, 1.0)
    return from_coo(rows, cols, n, n, row_scale=dinv, col_scale=None)


# ---------------------------------------------------------------------------
# Dynamic coarsening (device-side)
# ---------------------------------------------------------------------------

def coarsen_groups(tiles: jax.Array) -> jax.Array:
    """Stitch (..., GROUP) uint16 4x4 tiles into (..., TILE) uint32 words.

    Word ``i`` (one per matrix row in the tile-row) has bit ``t*4+j`` set iff
    tile ``t`` has bit ``i*4+j`` set — i.e. 8 tiles concatenated horizontally.
    TPU analogue of the paper's Step ③ shfl-based bit-concatenate.
    """
    t32 = tiles.astype(jnp.uint32)
    j = jnp.arange(TILE, dtype=jnp.uint32)                  # in-tile column
    i = jnp.arange(TILE, dtype=jnp.uint32)                  # in-tile row
    tpos = jnp.arange(GROUP, dtype=jnp.uint32)              # tile slot
    # bit (i*4 + j) of tile t  ->  bit (t*4 + j) of word i
    bits = (t32[..., None, :, None] >> (i[:, None, None] * TILE + j)) & 1
    words = jnp.sum(bits << (tpos[:, None] * TILE + j), axis=(-2, -1),
                    dtype=jnp.uint32)
    return words  # (..., TILE)


def group_neighbor_ids(col_idx: jax.Array) -> jax.Array:
    """(..., GROUP) tile-columns -> (..., GROUP_COLS) gathered column ids."""
    offs = jnp.arange(TILE, dtype=col_idx.dtype)
    return (col_idx[..., :, None] * TILE + offs).reshape(
        *col_idx.shape[:-1], GROUP_COLS)


def pad_frdc(m: FRDCMatrix, n_rows: int, n_cols: Optional[int] = None,
             n_groups: Optional[int] = None) -> FRDCMatrix:
    """Zero-pad an FRDC matrix to fixed bucket dimensions (serving shape
    buckets — one jit trace per bucket, zero steady-state recompiles).

    Padded groups hold zero tiles mapped to tile-row 0, which contribute
    nothing to any aggregation: the fp path masks lanes with the tile bits,
    and both trinary popc modes yield 0 for an all-zero adjacency word
    (``2*popc(0&b) - popc(0) == popc(0&b) - popc(0&~b) == 0``). Padded rows
    and columns carry no bits, so padded node slots never mix with real ones.

    Caveat: the BSpMM ``B?F`` variants rescale their popc counts by the
    GLOBAL ``mean(col_scale)`` (the paper's factorization-vector
    approximation, bspmm.py) — column padding appends 1.0 scales and shifts
    that mean, so those two variants are NOT padding-invariant on scaled
    adjacencies. Exact for everything the serving plans run: FBF/FBB, BBB,
    and B?F on unscaled (0/1) adjacencies.

    Array-namespace agnostic: a numpy-backed matrix (``from_coo(device=
    False)``, the serving extract stage) pads with numpy — no device work
    and no per-shape eager-op compiles on the per-batch hot path; a
    device-backed matrix pads with jnp exactly as before.
    """
    n_cols = n_rows if n_cols is None else n_cols
    if n_rows < m.n_rows or n_cols < m.n_cols:
        raise ValueError(f"bucket ({n_rows},{n_cols}) smaller than matrix "
                         f"({m.n_rows},{m.n_cols})")
    xp = np if isinstance(m.tiles, np.ndarray) else jnp
    g = m.n_groups
    n_groups = g if n_groups is None else max(n_groups, g)
    pad_g = n_groups - g
    n_tr = -(-n_rows // TILE)
    grp_ptr = xp.concatenate([
        m.grp_ptr,
        xp.full((n_tr - m.n_tile_rows,), m.grp_ptr[-1], xp.int32)])

    def _pad_scale(s, n_old, n_new):
        if s is None:
            return None
        return xp.concatenate([s, xp.ones((n_new - n_old,), s.dtype)])

    return FRDCMatrix(
        tiles=xp.pad(m.tiles, ((0, pad_g), (0, 0))),
        col_idx=xp.pad(m.col_idx, ((0, pad_g), (0, 0))),
        group_row=xp.pad(m.group_row, (0, pad_g)),
        group_first=xp.pad(m.group_first, (0, pad_g)),
        grp_ptr=grp_ptr, n_rows=int(n_rows), n_cols=int(n_cols), nnz=m.nnz,
        row_scale=_pad_scale(m.row_scale, m.n_rows, n_rows),
        col_scale=_pad_scale(m.col_scale, m.n_cols, n_cols),
    )


def align_tile(n: int) -> int:
    """Round up to the tile grid (min one tile) — the per-shard uniform dims
    of the SPMD layer executor are tile-aligned so every shard's padded FRDC
    block and operand rows share one static shape."""
    return -(-max(int(n), 1) // TILE) * TILE


def pad_frdc_uniform(mats, n_rows: int, n_cols: int,
                     n_groups: int) -> list:
    """Pad a per-shard family of FRDC matrices to ONE static shape.

    All three dims are shared: ``(n_rows, n_cols)`` must be tile-aligned
    covers of every matrix and ``n_groups`` a cover of every group count —
    the preconditions of :func:`stack_frdc`. Padding is exact for the
    serving variants (see :func:`pad_frdc`)."""
    if n_rows % TILE or n_cols % TILE:
        raise ValueError(f"uniform dims ({n_rows},{n_cols}) must be "
                         f"TILE({TILE})-aligned")
    return [pad_frdc(m, n_rows, n_cols, n_groups=n_groups) for m in mats]


def stack_frdc(mats) -> dict:
    """Stack uniformly padded FRDC matrices along a new leading shard axis.

    Returns the field dict (``tiles``/``col_idx``/``group_row``/
    ``group_first``/``grp_ptr`` + present scale vectors), each ``(P, ...)``
    — the operand layout a ``shard_map`` program consumes with a
    ``P('data')`` spec; slicing off the leading axis inside the program and
    rebuilding with the shared static dims recovers each shard's matrix."""
    m0 = mats[0]
    for m in mats[1:]:
        if (m.n_rows, m.n_cols, m.n_groups) != (m0.n_rows, m0.n_cols,
                                                m0.n_groups):
            raise ValueError(
                f"stack_frdc needs uniformly padded matrices, got "
                f"({m.n_rows},{m.n_cols},g{m.n_groups}) vs "
                f"({m0.n_rows},{m0.n_cols},g{m0.n_groups})")
        for f in ("row_scale", "col_scale"):
            if (getattr(m, f) is None) != (getattr(m0, f) is None):
                raise ValueError(f"stack_frdc: {f} present on some shards "
                                 "but not others")
    out = {f: jnp.stack([getattr(m, f) for m in mats])
           for f in ("tiles", "col_idx", "group_row", "group_first",
                     "grp_ptr")}
    for f in ("row_scale", "col_scale"):
        if getattr(m0, f) is not None:
            out[f] = jnp.stack([getattr(m, f) for m in mats])
    return out


def to_dense(m: FRDCMatrix, dtype=jnp.float32, apply_scales: bool = True):
    """Decode to a dense matrix — the oracle used by every BSpMM test."""
    tiles = np.asarray(m.tiles)
    col_idx = np.asarray(m.col_idx)
    group_row = np.asarray(m.group_row)
    out = np.zeros((m.n_tile_rows * TILE, -(-m.n_cols // TILE) * TILE), dtype=np.float32)
    for g in range(tiles.shape[0]):
        r0 = group_row[g] * TILE
        for t in range(GROUP):
            bits = int(tiles[g, t])
            if not bits:
                continue
            c0 = int(col_idx[g, t]) * TILE
            for i in range(TILE):
                for j in range(TILE):
                    if bits >> (i * TILE + j) & 1:
                        out[r0 + i, c0 + j] = 1.0
    out = out[:m.n_rows, :m.n_cols]
    if apply_scales:
        if m.row_scale is not None:
            out = out * np.asarray(m.row_scale)[:, None]
        if m.col_scale is not None:
            out = out * np.asarray(m.col_scale)[None, :]
    return jnp.asarray(out, dtype)


def stats(m: FRDCMatrix) -> dict:
    """Space accounting vs. fp32-CSR and dense-bit (paper Tables 3-5)."""
    tiles = np.asarray(m.tiles)
    nz_tiles = int((tiles != 0).sum())
    slots = tiles.size
    bit_slots = nz_tiles * TILE * TILE
    csr_fp32 = m.nnz * 8 + (m.n_rows + 1) * 4           # val+col + ptr
    dense_bits = m.n_rows * (-(-m.n_cols // 32)) * 4
    return dict(
        n_rows=m.n_rows, n_cols=m.n_cols, nnz=m.nnz,
        n_tiles=nz_tiles, n_groups=m.n_groups,
        pad_fraction=1.0 - nz_tiles / max(slots, 1),
        bits_per_edge=bit_slots / max(m.nnz, 1),
        frdc_bytes=m.nbytes(), csr_fp32_bytes=int(csr_fp32),
        dense_bit_bytes=int(dense_bits),
        vs_csr=csr_fp32 / max(m.nbytes(), 1),
    )
