"""Binarization ops: BIN / SCL / BN and the redundant-SCL elision (paper §3.1.2).

Bi-GCN-style binarization factorizes a full-precision matrix ``X`` as
``diag(alpha) @ sign(X)`` (row-wise) or ``sign(X) @ diag(beta)`` (column-wise),
where the scale vectors are L1 means — always positive. BitGNN's insight: when
a BIN immediately follows an SCL, the SCL cannot flip any sign, so it is
removed; the high-level ops below carry an ``elide_scale`` flag that the
abstraction layer sets when composing chains.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import bitops


class BinTensor(NamedTuple):
    """A binarized matrix: packed sign bits + positive scale factors.

    ``packed``: (..., rows, words) uint32, bits packed along the last logical
    axis (columns). ``scale``: broadcastable positive factors (row-wise
    (rows, 1) or column-wise (1, cols)) recovering magnitude; ``n``: logical
    column count (pre-padding).
    """
    packed: jax.Array
    scale: jax.Array
    n: int

    @property
    def shape(self):
        return (*self.packed.shape[:-1], self.n)


def bin_op(x: jax.Array, axis: int = -1) -> jax.Array:
    """BIN: sign-binarize-and-pack along ``axis`` (bit=1 iff x>=0)."""
    return bitops.sign_bits(x, axis=axis)


def row_l1_scale(x: jax.Array) -> jax.Array:
    """Bi-GCN row-wise scale: mean |x| per row (positive)."""
    return jnp.mean(jnp.abs(x), axis=-1, keepdims=True)


def col_l1_scale(x: jax.Array) -> jax.Array:
    """Bi-GCN column-wise scale: mean |x| per column (positive)."""
    return jnp.mean(jnp.abs(x), axis=-2, keepdims=True)


def binarize_matrix(x: jax.Array, scale: str = "row") -> BinTensor:
    """Factorize ``x ~= scale * sign(x)`` and pack the signs."""
    if scale == "row":
        s = row_l1_scale(x)
    elif scale == "col":
        s = col_l1_scale(x)
    elif scale == "none":
        s = jnp.ones((*x.shape[:-2], 1, 1), x.dtype)
    else:
        raise ValueError(scale)
    return BinTensor(packed=bin_op(x, axis=-1), scale=s, n=x.shape[-1])


def dequantize(t: BinTensor, dtype=jnp.float32) -> jax.Array:
    """Recover the (approximate) full-precision matrix for oracles/tests."""
    pm1 = bitops.unpack_pm1(t.packed, t.n, axis=-1, dtype=dtype)
    return pm1 * t.scale


def scl_op(x: jax.Array, scale: jax.Array, elide: bool = False) -> jax.Array:
    """SCL: multiply by (positive) scale factors; no-op when elided.

    ``elide=True`` is set by the abstraction layer when the consumer is a BIN:
    positive scaling never changes sign(x) (paper §3.1.2).
    """
    if elide:
        return x
    return x * scale


class BNParams(NamedTuple):
    gamma: jax.Array
    beta: jax.Array
    mean: jax.Array
    var: jax.Array
    eps: float = 1e-5


def bn_op(x: jax.Array, p: BNParams) -> jax.Array:
    """Inference-time batch norm (affine with running stats)."""
    inv = p.gamma * jax.lax.rsqrt(p.var + p.eps)
    return x * inv + (p.beta - p.mean * inv)


def bn_bin_threshold(p: BNParams) -> jax.Array:
    """Fold BN into the following BIN: sign(BN(x)) == (x >= t) when gamma>0.

    Returns the threshold ``t = mean - beta*sqrt(var+eps)/gamma``. The fused
    form removes the affine entirely from the binarized path (beyond the
    paper's SCL elision, same spirit: affine ops feeding a sign are folded).
    Only valid where gamma > 0; callers fall back to bn_op+bin_op otherwise.
    """
    return p.mean - p.beta * jnp.sqrt(p.var + p.eps) / p.gamma


def straight_through_sign(x: jax.Array) -> jax.Array:
    """sign(x) in {-1,+1} with a straight-through (clipped identity) gradient.

    Used to TRAIN binary GNN/LM weights so accuracy-parity experiments can be
    run end-to-end (Bi-GCN's training recipe, §5 related work).
    """
    @jax.custom_vjp
    def _sign(v):
        return jnp.where(v >= 0, 1.0, -1.0).astype(v.dtype)

    def _fwd(v):
        return _sign(v), v

    def _bwd(v, g):
        return (g * (jnp.abs(v) <= 1.0).astype(g.dtype),)

    _sign.defvjp(_fwd, _bwd)
    return _sign(x)
