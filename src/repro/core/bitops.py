"""Bit-level primitives for BitGNN on TPU.

Conventions
-----------
* Bits are packed along a chosen axis into ``uint32`` words, LSB-first:
  bit ``j`` of word ``w`` holds element ``w*32 + j``.
* Binary activations/weights use the BNN convention: stored bit ``1`` means
  value ``+1``, stored bit ``0`` means value ``-1`` (paper §2.2).
* Binary adjacency uses the graph convention: bit ``1`` means an edge, ``0``
  means no edge (paper §3.2.2).
* Padding bits (introduced to round lengths up to multiples of 32) are ``0``
  in both operands; every dot-product below is pad-safe given that invariant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

WORD = 32
_U32 = jnp.uint32

popcount = jax.lax.population_count


def _bit_weights() -> jax.Array:
    return jnp.left_shift(jnp.uint32(1), jnp.arange(WORD, dtype=_U32))


def padded_words(n: int) -> int:
    """Number of uint32 words needed to hold ``n`` bits."""
    return (n + WORD - 1) // WORD


def pack_bits(bits: jax.Array, axis: int = -1) -> jax.Array:
    """Pack a {0,1}/bool array along ``axis`` into uint32 words (LSB-first).

    ``bits.shape[axis]`` need not be a multiple of 32; missing bits pad as 0.
    """
    bits = jnp.asarray(bits)
    axis = axis % bits.ndim
    n = bits.shape[axis]
    pad = (-n) % WORD
    if pad:
        widths = [(0, 0)] * bits.ndim
        widths[axis] = (0, pad)
        bits = jnp.pad(bits, widths)
    bits = jnp.moveaxis(bits, axis, -1)
    grouped = bits.reshape(*bits.shape[:-1], (n + pad) // WORD, WORD).astype(_U32)
    packed = jnp.sum(grouped * _bit_weights(), axis=-1, dtype=_U32)
    return jnp.moveaxis(packed, -1, axis)


def unpack_bits(packed: jax.Array, n: int, axis: int = -1) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns int32 {0,1} with length ``n``."""
    packed = jnp.asarray(packed, _U32)
    axis = axis % packed.ndim
    words = jnp.moveaxis(packed, axis, -1)
    bits = (words[..., :, None] >> jnp.arange(WORD, dtype=_U32)) & jnp.uint32(1)
    bits = bits.reshape(*words.shape[:-1], words.shape[-1] * WORD)[..., :n]
    return jnp.moveaxis(bits.astype(jnp.int32), -1, axis)


def sign_bits(x: jax.Array, axis: int = -1) -> jax.Array:
    """Binarize-and-pack: bit=1 iff x >= 0 (the BNN ``sign`` of paper §2.2)."""
    return pack_bits(x >= 0, axis=axis)


def unpack_pm1(packed: jax.Array, n: int, axis: int = -1,
               dtype=jnp.float32) -> jax.Array:
    """Unpack BNN-convention bits to ±1 values of ``dtype``."""
    bits = unpack_bits(packed, n, axis=axis)
    return (2 * bits - 1).astype(dtype)


# ---------------------------------------------------------------------------
# Word-level dot products (the paper's §2.2 / §3.2.2 identities).
# All reduce over the LAST axis (the packed-word axis) of their operands.
# ---------------------------------------------------------------------------

def xnor_dot(a: jax.Array, b: jax.Array, n_bits) -> jax.Array:
    """±1·±1 dot product: ``n - 2*popc(a XOR b)`` (paper §2.2).

    Pad-safe: pads are 0 in both, XOR of pads is 0, contributes nothing.
    """
    return jnp.asarray(n_bits, jnp.int32) - 2 * jnp.sum(
        popcount(a ^ b), axis=-1, dtype=jnp.int32)


def and_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """0/1·0/1 dot product: ``popc(a AND b)`` (paper §2.2)."""
    return jnp.sum(popcount(a & b), axis=-1, dtype=jnp.int32)


def trinary_dot_s2(a: jax.Array, b: jax.Array) -> jax.Array:
    """Adjacency(0/1)·activation(±1): ``popc(a&b) - popc(a&~b)`` (§3.2.2 S2)."""
    return jnp.sum(popcount(a & b).astype(jnp.int32)
                   - popcount(a & ~b).astype(jnp.int32), axis=-1)


def trinary_dot_s3(a: jax.Array, b: jax.Array) -> jax.Array:
    """Adjacency(0/1)·activation(±1): ``2*popc(a&b) - popc(a)`` (§3.2.2 S3)."""
    return jnp.sum(2 * popcount(a & b).astype(jnp.int32)
                   - popcount(a).astype(jnp.int32), axis=-1)


def trinary_dot_s1(a_bits: jax.Array, b_pm1: jax.Array) -> jax.Array:
    """§3.2.2 S1 — if/else on a's nonzeros, for UNPACKED operands.

    ``a_bits`` is {0,1}, ``b_pm1`` is ±1 (or full-precision). Reduces last axis.
    On TPU the if/else becomes a lane ``select`` — used by the F-activation
    variants where b never exists in packed form.
    """
    return jnp.sum(jnp.where(a_bits != 0, b_pm1, 0), axis=-1)


TRINARY_MODES = ("s1_select", "s2_and_andnot", "s3_two_popc")


def trinary_dot(a: jax.Array, b: jax.Array, mode: str = "s3_two_popc"):
    if mode == "s2_and_andnot":
        return trinary_dot_s2(a, b)
    if mode == "s3_two_popc":
        return trinary_dot_s3(a, b)
    raise ValueError(f"packed trinary mode must be s2/s3, got {mode!r}")


# ---------------------------------------------------------------------------
# 32x32 bit-matrix transpose (TPU replacement for ballot+brev, paper §3.3 ④).
# ---------------------------------------------------------------------------

def bit_transpose_32(words: jax.Array) -> jax.Array:
    """Transpose a 32x32 bit block.

    ``words``: (..., 32) uint32 where row k's bit f is element (k, f).
    Returns (..., 32) uint32 where row f's bit k is element (k, f).

    The GPU version uses ``__ballot_sync``+``__brev`` across a warp; on TPU we
    do a vectorized shift/mask gather — 32x32 bools staged through VREGs.
    """
    words = jnp.asarray(words, _U32)
    # bits[..., k, f] = bit f of word k
    bits = (words[..., :, None] >> jnp.arange(WORD, dtype=_U32)) & jnp.uint32(1)
    # out word f collects bit k at position k
    out = jnp.sum(bits.astype(_U32) * (jnp.uint32(1) << jnp.arange(
        WORD, dtype=_U32))[..., :, None], axis=-2, dtype=_U32)
    return out


# ---------------------------------------------------------------------------
# Reference (unpacked) matmul helpers used widely by oracles/tests.
# ---------------------------------------------------------------------------

def bmm_xnor_words(a_packed: jax.Array, b_packed: jax.Array,
                   n_bits) -> jax.Array:
    """(M, W) x (N, W) packed ±1 matmul -> (M, N) int32 via XNOR-popc."""
    return xnor_dot(a_packed[:, None, :], b_packed[None, :, :], n_bits)


def spmm_trinary_words(adj_packed: jax.Array, act_packed: jax.Array,
                       mode: str = "s3_two_popc") -> jax.Array:
    """(M, W) 0/1-adjacency x (N->bits over N) ±1 activations -> (M, F).

    ``adj_packed``: (M, W) bits over neighbor index.
    ``act_packed``: (F, W) bits over neighbor index (i.e. activations
    TRANSPOSED and packed along the node axis — the paper's Step ④ layout).
    """
    return trinary_dot(adj_packed[:, None, :], act_packed[None, :, :], mode)


@functools.partial(jax.jit, static_argnames=("n_bits",))
def packed_memory_bytes(packed: jax.Array, n_bits: int) -> jax.Array:  # pragma: no cover
    del n_bits
    return jnp.asarray(packed.size * packed.dtype.itemsize)
