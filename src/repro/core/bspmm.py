"""BSpMM — binary sparse(adjacency) x dense matmul over FRDC (paper §3.3).

Eight variants, named ``BSpMM.<X><A><O>``: X = dense-operand precision (the
activations), A = adjacency (always binary bits; ``weighted`` — i.e. carrying
the §3.1.2 factorization vectors — doubles the variant count), O = output.

Semantics (out = Adj_eff @ X):
  * FBF / FBB : fp activations; EXACT for factorized adjacencies
                (col scales fold into X rows, row scales fold out — and are
                elided when O==B since they are positive).
  * BBF / BBB : binary ±1 activations via the trinary popc dot-product
                (§3.2.2); per-neighbor scales cannot cross popc, so this is
                the paper's *binary aggregation approximation* — the same one
                behind "Ours (bin)" in Tables 3-5.

The group-wise math here (gather -> coarsen -> bit-transpose -> popc ->
binarize) is the exact algorithm of the Pallas kernel; this module is both
the CPU execution path and the kernel's structural reference.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from . import bitops
from .binarize import BinTensor
from .frdc import (FRDCMatrix, GROUP_COLS, TILE, coarsen_groups,
                   group_neighbor_ids)

BSPMM_VARIANTS = ("FBF", "FBB", "BBF", "BBB")
TRINARY_DEFAULT = "s3_two_popc"

# Pluggable execution backends: when set (see kernels.ops.serve_kernels),
# the fp aggregation / trinary-counts stages run through them instead of the
# jnp reference below. The hooks sit at the same semantic level as the
# reference helpers: fp(adj, x) -> (n_rows, F) with scales applied;
# bits(adj, x_packed, trinary_mode) -> (n_rows, Wf*32) int32 counts.
_FP_BACKEND: Optional[Callable] = None
_BITS_BACKEND: Optional[Callable] = None


@contextlib.contextmanager
def override_backends(fp: Optional[Callable] = None,
                      bits: Optional[Callable] = None):
    """Route BSpMM stages through alternative implementations (Pallas
    kernels). The override is consulted at call/trace time, so wrapping a
    jit trace bakes the backend into the compiled executable."""
    global _FP_BACKEND, _BITS_BACKEND
    prev = (_FP_BACKEND, _BITS_BACKEND)
    _FP_BACKEND, _BITS_BACKEND = fp, bits
    try:
        yield
    finally:
        _FP_BACKEND, _BITS_BACKEND = prev


def _pad_rows(x: jax.Array, multiple: int) -> jax.Array:
    pad = (-x.shape[0]) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


def _mask_from_words(a_words: jax.Array) -> jax.Array:
    """(G, TILE) uint32 -> (G, TILE, GROUP_COLS) {0,1} lane mask."""
    k = jnp.arange(GROUP_COLS, dtype=jnp.uint32)
    return ((a_words[..., None] >> k) & jnp.uint32(1)).astype(jnp.float32)


def _segment_rows(contrib: jax.Array, adj: FRDCMatrix) -> jax.Array:
    """(G, TILE, F) group contributions -> (n_rows, F) via segment-sum."""
    out = jax.ops.segment_sum(contrib, adj.group_row,
                              num_segments=adj.n_tile_rows)
    out = out.reshape(adj.n_tile_rows * TILE, contrib.shape[-1])
    return out[:adj.n_rows]


def _spmm_fp(adj: FRDCMatrix, x: jax.Array) -> jax.Array:
    """Exact Adj_eff @ X for fp X: gather + masked small-matmul per group.

    The (TILE, GROUP_COLS) x (GROUP_COLS, F) per-group product is the op the
    TPU kernel runs on the MXU (§3.3 'other variants': FB? loads fp rows
    directly, no bit-transpose needed).
    """
    if adj.col_scale is not None:
        x = x * adj.col_scale[:, None].astype(x.dtype)
    xp = _pad_rows(x, TILE)
    nbr = group_neighbor_ids(adj.col_idx)          # (G, 32)
    xg = xp[nbr]                                   # (G, 32, F)
    mask = _mask_from_words(coarsen_groups(adj.tiles)).astype(x.dtype)
    contrib = jnp.einsum("gkn,gnf->gkf", mask, xg)
    out = _segment_rows(contrib, adj)
    if adj.row_scale is not None:
        out = out * adj.row_scale[:, None].astype(out.dtype)
    return out


def _spmm_bits(adj: FRDCMatrix, xp: jax.Array,
               trinary_mode: str = TRINARY_DEFAULT) -> jax.Array:
    """Trinary popc aggregation of packed ±1 activations -> (n_rows, F) int32.

    ``xp``: (N_pad_to_TILE, Wf) uint32, features packed along the last axis.
    Per group: gather 32 neighbor rows, bit-transpose 32x32 blocks (Step ④),
    popc against the coarsened adjacency words (Step ⑤).
    """
    nbr = group_neighbor_ids(adj.col_idx)                   # (G, 32)
    bg = xp[nbr]                                            # (G, 32, Wf)
    bt = bitops.bit_transpose_32(jnp.swapaxes(bg, -1, -2))  # (G, Wf, 32)
    a_words = coarsen_groups(adj.tiles)                     # (G, TILE)
    a = a_words[:, :, None, None]                           # (G,T,1,1)
    b = bt[:, None, :, :]                                   # (G,1,Wf,32)
    if trinary_mode == "s3_two_popc":
        c = 2 * bitops.popcount(a & b).astype(jnp.int32) \
            - bitops.popcount(a).astype(jnp.int32)
    elif trinary_mode == "s2_and_andnot":
        c = bitops.popcount(a & b).astype(jnp.int32) \
            - bitops.popcount(a & ~b).astype(jnp.int32)
    else:
        raise ValueError(trinary_mode)
    contrib = c.reshape(c.shape[0], TILE, -1)               # (G, T, F)
    return _segment_rows(contrib, adj)


def bspmm(adj: FRDCMatrix, x: Union[jax.Array, BinTensor], variant: str,
          trinary_mode: str = TRINARY_DEFAULT, out_scale: bool = True):
    """Dispatch a BSpMM variant. ``x`` fp (N,F) for F??, BinTensor for B??."""
    if variant not in BSPMM_VARIANTS:
        raise ValueError(f"unknown BSpMM variant {variant!r}")
    xa, _, op = variant

    if xa == "F":
        full = (_FP_BACKEND or _spmm_fp)(adj, x)
        n_feat = x.shape[-1]
    else:
        assert isinstance(x, BinTensor)
        xp = _pad_rows(x.packed, TILE)
        counts = (_BITS_BACKEND or _spmm_bits)(
            adj, xp, trinary_mode).astype(jnp.float32)
        n_feat = x.n
        counts = counts[:, :n_feat] if counts.shape[-1] > n_feat else counts
        if op == "F":
            # paper's approximation: positive scales re-applied as a mean
            # factor after the bit aggregation ("multiplication with a
            # full-precision factorization vector", §3.1.2).
            full = counts * jnp.mean(x.scale)
            if adj.row_scale is not None:
                full = full * adj.row_scale[:, None]
            if adj.col_scale is not None:
                full = full * jnp.mean(adj.col_scale)
        else:
            full = counts   # every scale is positive -> elided by BIN

    if op == "F":
        return full
    scale = jnp.mean(jnp.abs(full), axis=-1, keepdims=True) if out_scale \
        else jnp.ones((full.shape[0], 1), full.dtype)
    return BinTensor(packed=bitops.sign_bits(full[:, :n_feat], axis=-1),
                     scale=scale, n=n_feat)


def spmm_reference_fp(adj_dense: jax.Array, x: jax.Array) -> jax.Array:
    """Dense oracle: Adj_eff @ X with a decoded dense adjacency."""
    return adj_dense @ x
