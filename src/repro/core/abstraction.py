"""Two-level BitGNN abstraction (paper §3.1.2).

Low level: the BMM / BSpMM / ADD / CONCAT variant registry with three-letter
precision suffixes and static TYPE-CHECKING of chains ("as long as the output
precision of a predecessor block matches the input precision of its successor,
the correctness of types is guaranteed").

High level: fused drop-in blocks —
  * ``MMSpMM`` — the GCNConv pattern (BMM immediately followed by BSpMM),
    4 legal precision pairings, with automatic re-binarization elision:
    when BMM.? ?B feeds BSpMM.B??, the BMM skips its output-scale compute
    entirely (positive scale would be elided by the consumer's popc path);
  * ``MMAdd`` — the SAGEConv pattern (BMM followed by self-connection ADD).

Users convert a GNN by swapping layers for high-level blocks; the tuner
(:mod:`repro.core.tuner`) searches the legal variant space automatically.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from . import bmm as bmm_mod
from . import bspmm as bspmm_mod
from .binarize import BinTensor
from .frdc import FRDCMatrix

Tensor = Union[jax.Array, BinTensor]


def precision_of(x: Tensor) -> str:
    return "B" if isinstance(x, BinTensor) else "F"


# ---------------------------------------------------------------------------
# Low level
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpVariant:
    """A registered low-level op variant."""
    kind: str        # "BMM" | "BSpMM" | "ADD" | "CONCAT"
    suffix: str      # e.g. "FBF"
    fn: Callable

    @property
    def name(self) -> str:
        return f"{self.kind}.{self.suffix}"

    @property
    def in_precision(self) -> str:
        return self.suffix[0]

    @property
    def out_precision(self) -> str:
        return self.suffix[-1]


def _add_fff(a, b):
    return a + b


def _add_bbf(a: BinTensor, b: BinTensor):
    """ADD.BBF: sum two binary tensors into full precision (dequantized add).

    Mixed-precision ADD operands are excluded by design (paper §3.1.2: "mixed
    precisions of operands for these two operations are not meaningful").
    """
    from .binarize import dequantize
    return dequantize(a) + dequantize(b)


def _concat_fff(a, b):
    return jnp.concatenate([a, b], axis=-1)


def _concat_bbb(a: BinTensor, b: BinTensor):
    if a.n % 32 == 0:
        packed = jnp.concatenate([a.packed, b.packed], axis=-1)
        return BinTensor(packed=packed, scale=jnp.maximum(a.scale, b.scale),
                         n=a.n + b.n)
    from . import bitops
    bits = jnp.concatenate([bitops.unpack_bits(a.packed, a.n),
                            bitops.unpack_bits(b.packed, b.n)], axis=-1)
    return BinTensor(packed=bitops.pack_bits(bits),
                     scale=jnp.maximum(a.scale, b.scale), n=a.n + b.n)


REGISTRY: Dict[str, OpVariant] = {}


def _register(kind: str, suffix: str, fn: Callable) -> None:
    v = OpVariant(kind, suffix, fn)
    REGISTRY[v.name] = v


for _s in bmm_mod.BMM_VARIANTS:
    _register("BMM", _s, (lambda s: lambda x, w, **kw: bmm_mod.bmm(x, w, s, **kw))(_s))
for _s in bspmm_mod.BSPMM_VARIANTS:
    _register("BSpMM", _s, (lambda s: lambda a, x, **kw: bspmm_mod.bspmm(a, x, s, **kw))(_s))
_register("ADD", "FFF", _add_fff)
_register("ADD", "BBF", _add_bbf)
_register("CONCAT", "FFF", _concat_fff)
_register("CONCAT", "BBB", _concat_bbb)


def op(name: str) -> OpVariant:
    if name not in REGISTRY:
        raise KeyError(f"{name!r} not registered; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def check_chain(*names: str) -> None:
    """Static precision type-check of an op chain (§3.1.2 guarantee)."""
    for a, b in itertools.pairwise(names):
        va, vb = op(a), op(b)
        if va.out_precision != vb.in_precision:
            raise TypeError(
                f"precision mismatch: {va.name} outputs {va.out_precision!r} "
                f"but {vb.name} expects {vb.in_precision!r}")


# ---------------------------------------------------------------------------
# High level
# ---------------------------------------------------------------------------

# The four legal GCNConv pairings from §3.1.2.
MMSPMM_PAIRINGS: Sequence[tuple[str, str]] = (
    ("BMM.FBB", "BSpMM.BBB"),
    ("BMM.FBF", "BSpMM.FBB"),
    ("BMM.BBF", "BSpMM.FBF"),
    ("BMM.BBB", "BSpMM.BBF"),
    # plus fully-fp-out / fully-bin-in combinations used mid-network:
    ("BMM.FBF", "BSpMM.FBF"),
    ("BMM.BBB", "BSpMM.BBB"),
)


@dataclasses.dataclass(frozen=True)
class MMSpMM:
    """High-level fused block: BMM -> BSpMM (the GCNConv core).

    Re-binarization elision: when the BMM output is binary (feeding a binary
    BSpMM), ``out_scale=False`` is passed so no scale is ever computed —
    the §3.1.2 SCL-elision done at composition time rather than by a peephole.
    """
    mm: str
    spmm: str

    def __post_init__(self):
        check_chain(self.mm, self.spmm)

    def __call__(self, x: Tensor, wt, adj: FRDCMatrix, **kw):
        mm_v, sp_v = op(self.mm), op(self.spmm)
        elide = mm_v.out_precision == "B"
        h = mm_v.fn(x, wt, out_scale=not elide)
        return sp_v.fn(adj, h, **kw)


@dataclasses.dataclass(frozen=True)
class MMAdd:
    """High-level fused block: two BMMs merged by ADD (the SAGEConv core)."""
    mm_self: str
    mm_agg: str
    add: str = "ADD.FFF"

    def __call__(self, x_self: Tensor, w1, x_agg: Tensor, w2):
        a = op(self.mm_self).fn(x_self, w1)
        b = op(self.mm_agg).fn(x_agg, w2)
        return op(self.add).fn(a, b)


def legal_mmspmm_variants() -> Sequence[MMSpMM]:
    return tuple(MMSpMM(a, b) for a, b in MMSPMM_PAIRINGS)
